//! Progress-based fluid-flow network model with max-min fair sharing.
//!
//! Every transfer (an RDMA WR payload, an NVLink copy) is a **flow** with a
//! byte count and a path. At any instant each flow has a rate; rates are the
//! max-min fair allocation over link capacities. When the flow set changes
//! (start / finish / link up / down) all affected completion times are
//! re-derived; stale completion events are invalidated by a per-flow
//! generation counter (the owner passes the generation back on dispatch).
//!
//! This is the standard "fluid" DES network model: accurate for the
//! bandwidth-dominated regime the paper's figures live in, and fast — the
//! allocator is O(links × flows) per change with tiny constants.

use std::collections::HashMap;

use crate::sim::SimTime;
use crate::topology::{Fabric, LinkId, LinkKind, Path};
use crate::trace::{TraceEvent, Tracer};

/// Identifier of an in-flight flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

/// Opaque tag the owner attaches to a flow to route its completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowMeta(pub u64);

/// "Schedule (or reschedule) a completion check for `flow` at `at`."
/// Returned by every mutating call; the owner turns these into engine events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowTimer {
    pub flow: FlowId,
    pub gen: u32,
    pub at: SimTime,
}

#[derive(Debug, Clone)]
struct Flow {
    path: Path,
    remaining: f64, // bytes
    rate_bpns: f64, // bytes per ns (0 when stalled)
    last_update: SimTime,
    gen: u32,
    meta: FlowMeta,
    /// Extra fixed latency charged at the end (propagation + NIC setup);
    /// already folded into the first completion estimate.
    tail_latency_ns: u64,
    tail_charged: bool,
    /// Set while the flow is stalled by a dead link (drives the
    /// FlowStalled/FlowResumed trace transitions).
    was_stalled: bool,
}

#[derive(Debug, Clone)]
struct LinkState {
    capacity_bpns: f64,
    up: bool,
    kind: LinkKind,
}

/// The fluid network. Owns link state (mirrored from the [`Fabric`] at build
/// time, mutated through [`FlowNet::set_link_up`]) and the in-flight flows.
pub struct FlowNet {
    links: Vec<LinkState>,
    flows: HashMap<FlowId, Flow>,
    next_id: u64,
    /// Many-to-one goodput degradation per extra distinct sender on a
    /// receive port (PFC backpressure; see `NetConfig::incast_penalty`).
    incast_penalty: f64,
    /// Flight recorder (disabled by default; install via `set_tracer`).
    tracer: Tracer,
}

impl FlowNet {
    /// Build from the fabric: NIC links get scaled by `wire_efficiency`
    /// (headers/DCQCN overhead); NVLink and trunks are used as-is.
    pub fn from_fabric(fabric: &Fabric, wire_efficiency: f64, incast_penalty: f64) -> Self {
        let links = (0..fabric.num_links())
            .map(|i| {
                let l = fabric.link(LinkId(i));
                let eff = match l.kind {
                    LinkKind::NicUplinkTx | LinkKind::NicUplinkRx => wire_efficiency,
                    _ => 1.0,
                };
                LinkState {
                    capacity_bpns: l.capacity_gbps * 0.125 * eff,
                    up: l.up,
                    kind: l.kind,
                }
            })
            .collect();
        FlowNet {
            links,
            flows: HashMap::new(),
            next_id: 0,
            incast_penalty,
            tracer: Tracer::disabled(),
        }
    }

    /// Install a flight-recorder handle (flow start/rerate/stall/finish).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Start a flow of `bytes` over `path`; `tail_latency_ns` is the fixed
    /// (size-independent) component added to its completion time.
    /// Returns the id plus re-rate timers for every live flow whose
    /// completion moved (including the new one).
    pub fn start(
        &mut self,
        now: SimTime,
        path: Path,
        bytes: u64,
        tail_latency_ns: u64,
        meta: FlowMeta,
    ) -> (FlowId, Vec<FlowTimer>) {
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.settle(now);
        self.flows.insert(
            id,
            Flow {
                path,
                remaining: bytes as f64,
                rate_bpns: 0.0,
                last_update: now,
                gen: 0,
                meta,
                tail_latency_ns,
                tail_charged: false,
                was_stalled: false,
            },
        );
        self.tracer.record(now, TraceEvent::FlowStarted { flow: id.0, bytes });
        let timers = self.reallocate(now);
        (id, timers)
    }

    /// Called when the owner's completion event fires. Returns the meta if
    /// the flow really is done (and removes it); `None` if the event was
    /// stale (generation mismatch) or the flow still has bytes left
    /// (possible when it was stalled in between). The second element carries
    /// re-rate timers for the surviving flows.
    pub fn try_finish(
        &mut self,
        id: FlowId,
        gen: u32,
        now: SimTime,
    ) -> (Option<FlowMeta>, Vec<FlowTimer>) {
        let Some(f) = self.flows.get(&id) else { return (None, Vec::new()) };
        if f.gen != gen {
            return (None, Vec::new());
        }
        self.settle(now);
        let f = self.flows.get(&id).unwrap();
        // Completion fires after the remaining bytes drained AND the tail
        // latency elapsed; settle() guarantees progress accounting, so if
        // remaining is ~0 we are done.
        if f.remaining > 0.5 {
            // Stalled or re-rated after this event was scheduled; a fresher
            // timer exists (or the flow is stalled awaiting link-up).
            return (None, Vec::new());
        }
        let meta = f.meta;
        self.flows.remove(&id);
        self.tracer.record(now, TraceEvent::FlowFinished { flow: id.0 });
        let timers = self.reallocate(now);
        (Some(meta), timers)
    }

    /// Abort a flow (failover kills the primary-QP flows). Returns re-rate
    /// timers for the survivors.
    pub fn kill(&mut self, id: FlowId, now: SimTime) -> Vec<FlowTimer> {
        self.settle(now);
        if self.flows.remove(&id).is_some() {
            self.tracer.record(now, TraceEvent::FlowKilled { flow: id.0 });
            self.reallocate(now)
        } else {
            Vec::new()
        }
    }

    /// Bytes still to drain for an in-flight flow (None if finished/killed).
    pub fn remaining(&self, id: FlowId) -> Option<u64> {
        self.flows.get(&id).map(|f| f.remaining.max(0.0) as u64)
    }

    /// Is the flow currently stalled (rate 0, e.g. its path has a dead link)?
    pub fn is_stalled(&self, id: FlowId) -> Option<bool> {
        self.flows.get(&id).map(|f| f.rate_bpns <= 0.0)
    }

    /// Bring a link up or down. Down links stall their flows (rate 0) —
    /// the RDMA layer owns the retry/timeout semantics on top.
    pub fn set_link_up(&mut self, link: LinkId, up: bool, now: SimTime) -> Vec<FlowTimer> {
        self.settle(now);
        self.links[link.0].up = up;
        self.reallocate(now)
    }

    pub fn link_up(&self, link: LinkId) -> bool {
        self.links[link.0].up
    }

    /// Current rate of a flow in Gbps (diagnostics / monitor ground truth).
    pub fn rate_gbps(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.rate_bpns * 8.0)
    }

    /// Advance every flow's progress to `now` at its current rate.
    fn settle(&mut self, now: SimTime) {
        for f in self.flows.values_mut() {
            let dt = now.since(f.last_update).as_ns() as f64;
            f.remaining = (f.remaining - dt * f.rate_bpns).max(0.0);
            f.last_update = now;
        }
    }

    /// Recompute max-min fair rates; bump generations; emit fresh timers.
    fn reallocate(&mut self, now: SimTime) -> Vec<FlowTimer> {
        // Effective capacity per link: 0 when down; incast-degraded on
        // receive ports fed by multiple *distinct sender ports*. Chunks of
        // one sender share its egress serially and are not incast — only a
        // true many-to-one fan-in triggers PFC backpressure (§Appendix G
        // phase 2).
        let mut senders_per_link: HashMap<usize, Vec<usize>> = HashMap::new();
        for f in self.flows.values() {
            let Some(first) = f.path.links.first() else { continue };
            for l in &f.path.links {
                if matches!(self.links[l.0].kind, LinkKind::NicUplinkRx) {
                    let v = senders_per_link.entry(l.0).or_default();
                    if !v.contains(&first.0) {
                        v.push(first.0);
                    }
                }
            }
        }
        let eff_cap: Vec<f64> = self
            .links
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if !l.up {
                    return 0.0;
                }
                let n = senders_per_link.get(&i).map_or(0, |v| v.len());
                if n > 1 && matches!(l.kind, LinkKind::NicUplinkRx) {
                    l.capacity_bpns / (1.0 + self.incast_penalty * (n - 1) as f64)
                } else {
                    l.capacity_bpns
                }
            })
            .collect();

        // Max-min water filling. Ids are SORTED: the allocation itself is
        // order-independent, but the floating-point residual-capacity
        // bookkeeping and the order timers (and trace records) are emitted
        // are not — iterating in HashMap order would leak the per-process
        // hasher seed into event tie-breaking and break the bit-identical
        // trace contract (DESIGN.md, "Determinism contract").
        let mut ids: Vec<FlowId> = self.flows.keys().copied().collect();
        ids.sort_unstable();
        let mut rate: HashMap<FlowId, f64> = HashMap::with_capacity(ids.len());
        let mut frozen: HashMap<FlowId, bool> =
            ids.iter().map(|&i| (i, false)).collect();
        // Flows crossing any dead link are stalled outright.
        for &id in &ids {
            let f = &self.flows[&id];
            if f.path.links.iter().any(|l| eff_cap[l.0] <= 0.0) {
                rate.insert(id, 0.0);
                frozen.insert(id, true);
            }
        }
        let mut remaining_cap = eff_cap.clone();
        loop {
            // Count unfrozen flows per link.
            let mut unfrozen_per_link = vec![0u32; self.links.len()];
            let mut any_unfrozen = false;
            for &id in &ids {
                if frozen[&id] {
                    continue;
                }
                any_unfrozen = true;
                for l in &self.flows[&id].path.links {
                    unfrozen_per_link[l.0] += 1;
                }
            }
            if !any_unfrozen {
                break;
            }
            // Bottleneck link: minimal fair share.
            let mut best: Option<(usize, f64)> = None;
            for (i, &n) in unfrozen_per_link.iter().enumerate() {
                if n == 0 {
                    continue;
                }
                let share = remaining_cap[i] / n as f64;
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((i, share));
                }
            }
            let Some((bottleneck, share)) = best else { break };
            // Freeze every unfrozen flow crossing the bottleneck at `share`.
            let freezing: Vec<FlowId> = ids
                .iter()
                .copied()
                .filter(|id| {
                    !frozen[id]
                        && self.flows[id].path.links.iter().any(|l| l.0 == bottleneck)
                })
                .collect();
            for id in freezing {
                rate.insert(id, share);
                frozen.insert(id, true);
                for l in &self.flows[&id].path.links {
                    remaining_cap[l.0] = (remaining_cap[l.0] - share).max(0.0);
                }
            }
        }

        // Apply rates, bump generations, emit timers — but ONLY for flows
        // whose rate actually changed (>0.1% relative): an unchanged rate
        // means the outstanding completion timer is still exact, and
        // skipping the re-emit removes the O(flows) stale-event storm per
        // network change (§Perf L3: this is the simulator's hot path).
        let mut timers = Vec::with_capacity(ids.len());
        for &id in &ids {
            let f = self.flows.get_mut(&id).expect("ids snapshot is current");
            let r = rate.get(&id).copied().unwrap_or(0.0);
            let unchanged = f.tail_charged
                && f.rate_bpns > 0.0
                && (r - f.rate_bpns).abs() <= 1e-3 * f.rate_bpns;
            if unchanged {
                continue;
            }
            let old = f.rate_bpns;
            // Trace only meaningful transitions: stall (>0 → 0 with bytes
            // left), resume (stalled → moving), and re-rates beyond 10 % —
            // the fair-share wobble every start/finish causes would
            // otherwise dominate the ring.
            if self.tracer.enabled() {
                if old > 0.0 && r <= 0.0 && f.remaining > 0.5 {
                    self.tracer.record(now, TraceEvent::FlowStalled { flow: id.0 });
                } else if old <= 0.0 && r > 0.0 && f.was_stalled {
                    self.tracer
                        .record(now, TraceEvent::FlowResumed { flow: id.0, scope: "flow" });
                } else if old > 0.0 && r > 0.0 && (r - old).abs() > 0.10 * old {
                    self.tracer.record(now, TraceEvent::FlowRerated { flow: id.0, gbps: r * 8.0 });
                }
            }
            if r <= 0.0 && old > 0.0 {
                f.was_stalled = true;
            } else if r > 0.0 {
                f.was_stalled = false;
            }
            f.rate_bpns = r;
            f.gen += 1;
            if r > 0.0 {
                let mut eta_ns = (f.remaining / r).ceil() as u64;
                if !f.tail_charged {
                    eta_ns += f.tail_latency_ns;
                    // The tail is charged once; if re-rated later the
                    // remaining-bytes math still owes it, so mark only when
                    // the first timer includes it. To stay conservative we
                    // fold the tail into `remaining` as rate-equivalent
                    // bytes instead: simpler — extend remaining.
                    f.remaining += f.tail_latency_ns as f64 * r;
                    f.tail_charged = true;
                }
                timers.push(FlowTimer { flow: id, gen: f.gen, at: now + SimTime::ns(eta_ns) });
            }
            // Stalled flows get no timer — the RDMA retry layer owns them.
        }
        timers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;
    use crate::topology::{NicId, NodeId, PortId};

    fn fabric() -> Fabric {
        Fabric::build(&TopologyConfig { num_nodes: 2, ..Default::default() })
    }

    fn port(node: usize, nic: usize) -> PortId {
        PortId { nic: NicId { node: NodeId(node), local: nic }, port: 0 }
    }

    /// Drive the net to completion of a single flow, returning finish time.
    fn run_to_completion(net: &mut FlowNet, timers: Vec<FlowTimer>) -> Vec<(SimTime, FlowMeta)> {
        let mut queue = timers;
        let mut done = Vec::new();
        while let Some(t) = queue.iter().min_by_key(|t| t.at).copied() {
            queue.retain(|x| *x != t);
            let (meta, more) = net.try_finish(t.flow, t.gen, t.at);
            if let Some(m) = meta {
                done.push((t.at, m));
            }
            queue.extend(more);
        }
        done
    }

    #[test]
    fn single_flow_runs_at_line_rate() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let bytes = 64 * 1024 * 1024u64; // 64MB
        let (_, timers) = net.start(SimTime::ZERO, path, bytes, 0, FlowMeta(1));
        let done = run_to_completion(&mut net, timers);
        assert_eq!(done.len(), 1);
        // 64MB at 400Gbps = 50 GB/s → ≈1.342 ms
        let ms = done[0].0.as_ms_f64();
        assert!((ms - 1.342).abs() < 0.01, "ms={ms}");
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path1 = f.path_inter(port(0, 0), port(1, 0));
        let path2 = f.path_inter(port(0, 0), port(1, 0)); // same links
        let bytes = 8 * 1024 * 1024u64;
        let (_, mut t1) = net.start(SimTime::ZERO, path1, bytes, 0, FlowMeta(1));
        let (_, t2) = net.start(SimTime::ZERO, path2, bytes, 0, FlowMeta(2));
        t1.extend(t2);
        let done = run_to_completion(&mut net, t1);
        assert_eq!(done.len(), 2);
        // Both should finish at ≈2× the solo time (fair halves).
        let solo_ns = 8.0 * 1024.0 * 1024.0 / (400.0 * 0.125);
        for (at, _) in &done {
            let ratio = at.as_ns() as f64 / solo_ns;
            assert!((ratio - 2.0).abs() < 0.05, "ratio={ratio}");
        }
    }

    #[test]
    fn disjoint_flows_do_not_interact() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let bytes = 4 * 1024 * 1024u64;
        let (_, mut ts) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
        let (_, t2) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 1)), bytes, 0, FlowMeta(2));
        ts.extend(t2);
        let done = run_to_completion(&mut net, ts);
        let solo_ns = (4.0f64 * 1024.0 * 1024.0 / (400.0 * 0.125)).ceil();
        for (at, _) in &done {
            assert!((at.as_ns() as f64 - solo_ns).abs() < 10.0);
        }
    }

    #[test]
    fn link_down_stalls_and_up_resumes() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let bytes = 8 * 1024 * 1024u64;
        let (id, timers) = net.start(SimTime::ZERO, path, bytes, 0, FlowMeta(7));
        // Take the port down halfway through.
        let half = SimTime::ns(timers[0].at.as_ns() / 2);
        let tx = f.port_tx(port(0, 0));
        let t_down = net.set_link_up(tx, false, half);
        assert!(t_down.is_empty(), "stalled flow must get no timer");
        assert_eq!(net.is_stalled(id), Some(true));
        // Old timer is stale now.
        let (meta, _) = net.try_finish(id, timers[0].gen, timers[0].at);
        assert!(meta.is_none());
        // Bring it back at t=1ms; remaining half drains.
        let up_at = SimTime::ms(1);
        let t_up = net.set_link_up(tx, true, up_at);
        assert_eq!(t_up.len(), 1);
        let done = run_to_completion(&mut net, t_up);
        assert_eq!(done.len(), 1);
        let expect_ns = 1_000_000.0 + (bytes as f64 / 2.0) / (400.0 * 0.125);
        assert!((done[0].0.as_ns() as f64 - expect_ns).abs() < 100.0);
    }

    #[test]
    fn tracer_records_stall_and_resume_transitions() {
        use crate::trace::{TraceSink, Tracer};
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let sink = TraceSink::new(1024, 1_000_000_000);
        net.set_tracer(Tracer::attached(sink.clone()));
        let path = f.path_inter(port(0, 0), port(1, 0));
        let (id, _) = net.start(SimTime::ZERO, path, 8 << 20, 0, FlowMeta(1));
        let tx = f.port_tx(port(0, 0));
        net.set_link_up(tx, false, SimTime::us(10));
        net.set_link_up(tx, true, SimTime::ms(1));
        let kinds: Vec<&str> = sink.records().iter().map(|r| r.ev.kind()).collect();
        let pos = |k: &str| kinds.iter().position(|x| *x == k);
        let started = pos("FlowStarted").expect("start recorded");
        let stalled = pos("FlowStalled").expect("stall recorded");
        let resumed = pos("FlowResumed").expect("resume recorded");
        assert!(started < stalled && stalled < resumed, "{kinds:?}");
        assert_eq!(net.is_stalled(id), Some(false));
    }

    #[test]
    fn incast_degrades_goodput_below_fair_share() {
        let f = fabric();
        // Two senders (node0 nic0, node0 nic1 → cross-rail) into ONE
        // receive port on node1 nic0.
        let mut fair = FlowNet::from_fabric(&f, 1.0, 0.0);
        let mut incast = FlowNet::from_fabric(&f, 1.0, 0.5);
        let bytes = 4 * 1024 * 1024u64;
        for net in [&mut fair, &mut incast] {
            let mut ts = Vec::new();
            let (_, t1) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
            let (_, t2) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 0)), bytes, 0, FlowMeta(2));
            ts.extend(t1);
            ts.extend(t2);
            let done = run_to_completion(net, ts);
            assert_eq!(done.len(), 2);
        }
        // With penalty 0.5 and 2 flows, effective receive capacity is
        // 400/(1.5) ≈ 267 Gbps vs 400 — re-run to compare finish times.
        let mut fair = FlowNet::from_fabric(&f, 1.0, 0.0);
        let mut slow = FlowNet::from_fabric(&f, 1.0, 0.5);
        let mut t_fair = SimTime::ZERO;
        let mut t_slow = SimTime::ZERO;
        for (net, out) in [(&mut fair, &mut t_fair), (&mut slow, &mut t_slow)] {
            let mut ts = Vec::new();
            let (_, t1) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
            let (_, t2) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 0)), bytes, 0, FlowMeta(2));
            ts.extend(t1);
            ts.extend(t2);
            let done = run_to_completion(net, ts);
            *out = done.iter().map(|(t, _)| *t).max().unwrap();
        }
        let ratio = t_slow.as_ns() as f64 / t_fair.as_ns() as f64;
        assert!((ratio - 1.5).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn tail_latency_added_once() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let (_, timers) = net.start(SimTime::ZERO, path, 1024, 5_000, FlowMeta(1));
        let done = run_to_completion(&mut net, timers);
        // 1KB at 400Gbps ≈ 20ns + 5000ns tail.
        let ns = done[0].0.as_ns();
        assert!((5_015..5_030).contains(&ns), "ns={ns}");
    }

    #[test]
    fn kill_removes_flow_and_rerates_survivors() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let bytes = 8 * 1024 * 1024u64;
        let (a, mut ts) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
        let (_b, t2) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(2));
        ts.extend(t2);
        // Kill A at 25% of the shared schedule; B should then run at full rate.
        let kill_at = SimTime::ns(ts[0].at.as_ns() / 4);
        let mut timers = net.kill(a, kill_at);
        assert_eq!(net.active_flows(), 1);
        assert_eq!(timers.len(), 1);
        let done = run_to_completion(&mut net, std::mem::take(&mut timers));
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].1, FlowMeta(2));
    }

    #[test]
    fn stale_generation_ignored() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let (id, t1) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), 1 << 20, 0, FlowMeta(1));
        // Start a second flow → re-rates, bumping generation.
        let (_, _t2) =
            net.start(SimTime::ns(10), f.path_inter(port(0, 0), port(1, 0)), 1 << 20, 0, FlowMeta(2));
        let (meta, _) = net.try_finish(id, t1[0].gen, t1[0].at);
        assert!(meta.is_none(), "stale timer must not complete the flow");
    }
}
