//! Progress-based fluid-flow network model with max-min fair sharing.
//!
//! Every transfer (an RDMA WR payload, an NVLink copy) is a **flow** with a
//! byte count and a path. At any instant each flow has a rate; rates are the
//! max-min fair allocation over link capacities. When the flow set changes
//! (start / finish / link up / down) the affected completion times are
//! re-derived; stale completion events are invalidated by a per-flow
//! generation counter (the owner passes the generation back on dispatch).
//!
//! This is the standard "fluid" DES network model: accurate for the
//! bandwidth-dominated regime the paper's figures live in, and fast.
//!
//! # §Perf L3: incremental, component-scoped allocation
//!
//! Max-min water-filling decomposes over the connected components of the
//! bipartite flow↔link graph: capacity never moves between flows that share
//! no link (directly or transitively), so a change to one flow or link can
//! only re-rate the flows in *its* component. The allocator exploits that:
//!
//! - a persistent reverse index `link → sorted flow ids` (plus per-receive-
//!   port distinct-sender counts for the incast model) is maintained on
//!   every start/finish/kill;
//! - each change walks the component reachable from the mutated entity and
//!   re-runs water-filling only inside it — O(component) instead of the old
//!   O(links × flows) global pass;
//! - flows outside the component keep their rates, generations and
//!   outstanding timers untouched, and their progress accounting is *lazy*:
//!   `remaining` is materialized only when the rate actually changes, so the
//!   floating-point trajectory of an untouched flow is bit-identical whether
//!   or not unrelated reallocations happened in between.
//!
//! The old global algorithm survives as `FlowNet::reference_rates` (under
//! `cfg(any(test, debug_assertions, feature = "ref-alloc"))`): debug builds
//! cross-check every incremental result against it bit-for-bit, and
//! `FlowNet::set_reference_mode` forces a net to allocate globally so the
//! equivalence tests and `benches/flownet.rs` can compare the two end to end.
//! See DESIGN.md §"Perf L3: incremental allocation".

use std::collections::{HashMap, HashSet};

use crate::sim::SimTime;
use crate::topology::{Fabric, LinkId, LinkKind, Path};
use crate::trace::{TraceEvent, Tracer};
use crate::util::{CkptReader, CkptWriter};

/// Identifier of an in-flight flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

/// Opaque tag the owner attaches to a flow to route its completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowMeta(pub u64);

/// "Schedule (or reschedule) a completion check for `flow` at `at`."
/// Returned by every mutating call; the owner turns these into engine events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowTimer {
    pub flow: FlowId,
    pub gen: u32,
    pub at: SimTime,
}

/// §Perf L3 instrumentation: how much work the allocator does per change.
/// Deterministic (pure counters over simulated activity), so the numbers are
/// safe to emit into `BENCH_simcore.json`.
#[derive(Debug, Default, Clone, Copy)]
pub struct AllocStats {
    /// Reallocation passes (one per flow start/finish/kill or link batch).
    pub changes: u64,
    /// Flows examined across all passes (water-fill rounds + rate apply).
    pub flow_visits: u64,
    /// Lower bound on what the global reference allocator would have
    /// examined: the live-flow count summed over changes (its settle+apply
    /// floor — its water-fill rounds rescan every flow and visit more).
    pub global_floor: u64,
    /// Largest connected component (in flows) any pass walked.
    pub max_component: u64,
}

#[derive(Debug, Clone)]
struct Flow {
    path: Path,
    /// Payload bytes left **as of `rate_since`** (the last materialization
    /// point). The live value is `remaining - (now - rate_since) * rate`;
    /// it is snapshotted exactly once per rate change, never on unrelated
    /// reallocations — see the module docs on lazy progress.
    remaining: f64,
    rate_bpns: f64, // bytes per ns (0 when stalled)
    /// When the current rate took effect (and `remaining` was snapshotted).
    rate_since: SimTime,
    gen: u32,
    meta: FlowMeta,
    /// Fixed latency (propagation + NIC setup) charged **after** the last
    /// payload byte drains. A completion deadline, never folded into the
    /// byte account — folding made the tail stretch/shrink under re-rates.
    tail_latency_ns: u64,
    /// The instant the payload finished draining (set on materialization);
    /// completion fires at `drained_at + tail_latency_ns`.
    drained_at: Option<SimTime>,
    /// Set while the flow is stalled by a dead link (drives the
    /// FlowStalled/FlowResumed trace transitions).
    was_stalled: bool,
}

impl Flow {
    /// Payload bytes left at `now`, derived without mutating the snapshot.
    fn remaining_at(&self, now: SimTime) -> f64 {
        if self.rate_bpns <= 0.0 {
            return self.remaining;
        }
        let dt = now.since(self.rate_since).as_ns() as f64;
        (self.remaining - dt * self.rate_bpns).max(0.0)
    }

    /// When the payload drains (or drained). `None` while stalled with
    /// bytes left.
    fn drain_time(&self) -> Option<SimTime> {
        if let Some(d) = self.drained_at {
            return Some(d);
        }
        if self.rate_bpns > 0.0 {
            let eta = (self.remaining / self.rate_bpns).ceil() as u64;
            Some(self.rate_since + SimTime::ns(eta))
        } else {
            None
        }
    }

    /// Snapshot progress at `now`. Called exactly once per rate change in
    /// every allocation mode — the determinism contract depends on the
    /// materialization points (and therefore the FP rounding sequence)
    /// being identical between the incremental and reference allocators.
    fn materialize(&mut self, now: SimTime) {
        if self.rate_bpns > 0.0 {
            let before = self.remaining;
            let dt = now.since(self.rate_since).as_ns() as f64;
            self.remaining = (before - dt * self.rate_bpns).max(0.0);
            if self.remaining <= 0.0 && self.drained_at.is_none() {
                let eta = (before / self.rate_bpns).ceil() as u64;
                self.drained_at = Some(self.rate_since + SimTime::ns(eta));
            }
        }
        self.rate_since = now;
    }
}

#[derive(Debug, Clone)]
struct LinkState {
    capacity_bpns: f64,
    up: bool,
    kind: LinkKind,
}

/// The fluid network. Owns link state (mirrored from the [`Fabric`] at build
/// time, mutated through [`FlowNet::set_link_up`]) and the in-flight flows.
pub struct FlowNet {
    links: Vec<LinkState>,
    flows: HashMap<FlowId, Flow>,
    /// Reverse index: link → flow ids crossing it, kept **sorted** so the
    /// component walk and water-fill stay deterministic.
    link_flows: Vec<Vec<FlowId>>,
    /// Per-receive-port distinct-sender accounting for the incast model:
    /// `(sender egress link, flows from it)` pairs; the distinct-sender
    /// count is the vector length. Populated only for `NicUplinkRx` links.
    rx_senders: Vec<Vec<(usize, u32)>>,
    next_id: u64,
    /// Many-to-one goodput degradation per extra distinct sender on a
    /// receive port (PFC backpressure; see `NetConfig::incast_penalty`).
    incast_penalty: f64,
    /// Flight recorder (disabled by default; install via `set_tracer`).
    tracer: Tracer,
    /// Component-walk scratch: per-link visit stamps (epoch marking avoids
    /// an O(links) clear per change).
    link_stamp: Vec<u32>,
    stamp: u32,
    /// Water-fill scratch, valid only for the current component's links.
    cap_scratch: Vec<f64>,
    unfrozen_scratch: Vec<u32>,
    alloc: AllocStats,
    /// Force the global reference allocator for every pass (equivalence
    /// tests and the `flownet` bench drive a mirror net in this mode).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    force_global: bool,
}

impl FlowNet {
    /// Build from the fabric: NIC links get scaled by `wire_efficiency`
    /// (headers/DCQCN overhead); NVLink and trunks are used as-is.
    pub fn from_fabric(fabric: &Fabric, wire_efficiency: f64, incast_penalty: f64) -> Self {
        let links: Vec<LinkState> = (0..fabric.num_links())
            .map(|i| {
                let l = fabric.link(LinkId(i));
                let eff = match l.kind {
                    LinkKind::NicUplinkTx | LinkKind::NicUplinkRx => wire_efficiency,
                    _ => 1.0,
                };
                LinkState {
                    capacity_bpns: l.capacity_gbps * 0.125 * eff,
                    up: l.up,
                    kind: l.kind,
                }
            })
            .collect();
        let n = links.len();
        FlowNet {
            links,
            flows: HashMap::new(),
            link_flows: vec![Vec::new(); n],
            rx_senders: vec![Vec::new(); n],
            next_id: 0,
            incast_penalty,
            tracer: Tracer::disabled(),
            link_stamp: vec![0; n],
            stamp: 0,
            cap_scratch: vec![0.0; n],
            unfrozen_scratch: vec![0; n],
            alloc: AllocStats::default(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            force_global: false,
        }
    }

    /// Install a flight-recorder handle (flow start/rerate/stall/finish).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Allocate with the global reference algorithm instead of the
    /// component-scoped one. Output (rates, generations, timers, trace
    /// order) is bit-identical by contract; only the work differs.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_reference_mode(&mut self, on: bool) {
        self.force_global = on;
    }

    /// §Perf L3 work counters (see [`AllocStats`]).
    pub fn alloc_stats(&self) -> AllocStats {
        self.alloc
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Start a flow of `bytes` over `path`; `tail_latency_ns` is the fixed
    /// (size-independent) component added to its completion time.
    /// Returns the id plus re-rate timers for every flow whose completion
    /// moved (including the new one).
    pub fn start(
        &mut self,
        now: SimTime,
        path: Path,
        bytes: u64,
        tail_latency_ns: u64,
        meta: FlowMeta,
    ) -> (FlowId, Vec<FlowTimer>) {
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.index_add(id, &path);
        let seeds = path.links.clone();
        self.flows.insert(
            id,
            Flow {
                path,
                remaining: bytes as f64,
                rate_bpns: 0.0,
                rate_since: now,
                gen: 0,
                meta,
                tail_latency_ns,
                drained_at: None,
                was_stalled: false,
            },
        );
        self.tracer.record(now, TraceEvent::FlowStarted { flow: id.0, bytes });
        let timers = self.reallocate(now, &seeds);
        (id, timers)
    }

    /// Called when the owner's completion event fires. Returns the meta if
    /// the flow really is done (and removes it); `None` if the event was
    /// stale (generation mismatch) or the flow still has bytes left
    /// (possible when it was stalled in between). The second element carries
    /// re-rate timers for the surviving flows of the flow's component.
    pub fn try_finish(
        &mut self,
        id: FlowId,
        gen: u32,
        now: SimTime,
    ) -> (Option<FlowMeta>, Vec<FlowTimer>) {
        let Some(f) = self.flows.get(&id) else { return (None, Vec::new()) };
        if f.gen != gen {
            return (None, Vec::new());
        }
        // Lazy progress: derive the live byte count, no settle pass.
        if f.remaining_at(now) > 0.5 {
            // Stalled or re-rated after this event was scheduled; a fresher
            // timer exists (or the flow is stalled awaiting link-up).
            return (None, Vec::new());
        }
        // Payload drained — the fixed tail must have elapsed too. The tail
        // is a completion deadline anchored at the drain instant, so it is
        // immune to re-rates (it used to be folded into `remaining` as
        // rate-equivalent bytes, which stretched it under re-rating).
        let drained = f.drain_time().unwrap_or(now);
        if now < drained + SimTime::ns(f.tail_latency_ns) {
            return (None, Vec::new());
        }
        let f = self.flows.remove(&id).unwrap();
        self.index_remove(id, &f.path);
        self.tracer.record(now, TraceEvent::FlowFinished { flow: id.0 });
        let timers = self.reallocate(now, &f.path.links);
        (Some(f.meta), timers)
    }

    /// Abort a flow (failover kills the primary-QP flows). Returns re-rate
    /// timers for the survivors.
    pub fn kill(&mut self, id: FlowId, now: SimTime) -> Vec<FlowTimer> {
        // O(1) membership check first: failover double-kills are routine
        // and must not trigger an allocation pass (this used to settle
        // every live flow before discovering the id was gone).
        let Some(f) = self.flows.remove(&id) else { return Vec::new() };
        self.index_remove(id, &f.path);
        self.tracer.record(now, TraceEvent::FlowKilled { flow: id.0 });
        self.reallocate(now, &f.path.links)
    }

    /// Bytes still to drain for an in-flight flow at `now`
    /// (None if finished/killed).
    pub fn remaining(&self, id: FlowId, now: SimTime) -> Option<u64> {
        self.flows.get(&id).map(|f| f.remaining_at(now) as u64)
    }

    /// Is the flow currently stalled (rate 0, e.g. its path has a dead link)?
    pub fn is_stalled(&self, id: FlowId) -> Option<bool> {
        self.flows.get(&id).map(|f| f.rate_bpns <= 0.0)
    }

    /// Bring a link up or down. Down links stall their flows (rate 0) —
    /// the RDMA layer owns the retry/timeout semantics on top.
    pub fn set_link_up(&mut self, link: LinkId, up: bool, now: SimTime) -> Vec<FlowTimer> {
        self.set_links_up(&[link], up, now)
    }

    /// Batch form: links that change state together (a physical port flap
    /// is tx + rx at once) trigger **one** component recompute, not one per
    /// link.
    pub fn set_links_up(&mut self, links: &[LinkId], up: bool, now: SimTime) -> Vec<FlowTimer> {
        for &l in links {
            self.links[l.0].up = up;
        }
        self.reallocate(now, links)
    }

    pub fn link_up(&self, link: LinkId) -> bool {
        self.links[link.0].up
    }

    /// A link's current capacity in bytes/ns (§Soak: the fault scheduler
    /// reads the base value before degrading and when recovering).
    pub fn link_capacity_bpns(&self, link: LinkId) -> f64 {
        self.links[link.0].capacity_bpns
    }

    /// Change a link's capacity (§Soak: straggler NICs and slow switches
    /// are *capacity* faults, not flaps — traffic keeps flowing, slowly,
    /// which is exactly what the monitor must pinpoint). Triggers one
    /// component recompute, like a link state change.
    pub fn set_link_capacity(
        &mut self,
        link: LinkId,
        capacity_bpns: f64,
        now: SimTime,
    ) -> Vec<FlowTimer> {
        let was = self.links[link.0].capacity_bpns;
        let new = capacity_bpns.max(0.0);
        self.links[link.0].capacity_bpns = new;
        // A runtime capacity change is a fault-injection / degradation
        // action — rare and causally load-bearing, so it goes in the ring
        // (the RCA layer opens a degrade window from `gbps < was_gbps`).
        if self.tracer.enabled() && (new - was).abs() > f64::EPSILON {
            self.tracer.record(
                now,
                TraceEvent::LinkCapacity {
                    link: link.0,
                    gbps: new * 8.0,
                    was_gbps: was * 8.0,
                },
            );
        }
        self.reallocate(now, &[link])
    }

    /// Serialize the durable state (§Soak checkpointing). Requires
    /// quiescence: checkpoints sit on op-burst boundaries where no flow is
    /// live, so only link state and counters need to survive.
    pub fn save(&self, w: &mut CkptWriter) {
        assert!(self.flows.is_empty(), "FlowNet checkpoint requires quiescence (live flows)");
        w.usize("nlinks", self.links.len());
        for l in &self.links {
            w.f64("cap", l.capacity_bpns);
            w.bool("up", l.up);
        }
        w.u64("nextflow", self.next_id);
        w.u64("achanges", self.alloc.changes);
        w.u64("avisits", self.alloc.flow_visits);
        w.u64("afloor", self.alloc.global_floor);
        w.u64("acomp", self.alloc.max_component);
    }

    /// Restore the state saved by [`FlowNet::save`] into a freshly built
    /// net over the same fabric.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        let n = r.usize("nlinks")?;
        if n != self.links.len() {
            return Err(format!("link count skew: checkpoint {n}, net {}", self.links.len()));
        }
        for l in &mut self.links {
            l.capacity_bpns = r.f64("cap")?;
            l.up = r.bool("up")?;
        }
        self.next_id = r.u64("nextflow")?;
        self.alloc.changes = r.u64("achanges")?;
        self.alloc.flow_visits = r.u64("avisits")?;
        self.alloc.global_floor = r.u64("afloor")?;
        self.alloc.max_component = r.u64("acomp")?;
        Ok(())
    }

    /// Current rate of a flow in Gbps (diagnostics / monitor ground truth).
    pub fn rate_gbps(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.rate_bpns * 8.0)
    }

    // ------------------------------------------------------------------
    // Reverse index maintenance
    // ------------------------------------------------------------------

    fn index_add(&mut self, id: FlowId, path: &Path) {
        for l in &path.links {
            let v = &mut self.link_flows[l.0];
            if let Err(pos) = v.binary_search(&id) {
                v.insert(pos, id);
            }
        }
        if let Some(first) = path.links.first() {
            for l in &path.links {
                if matches!(self.links[l.0].kind, LinkKind::NicUplinkRx) {
                    let senders = &mut self.rx_senders[l.0];
                    match senders.iter_mut().find(|(s, _)| *s == first.0) {
                        Some((_, n)) => *n += 1,
                        None => senders.push((first.0, 1)),
                    }
                }
            }
        }
    }

    fn index_remove(&mut self, id: FlowId, path: &Path) {
        for l in &path.links {
            let v = &mut self.link_flows[l.0];
            if let Ok(pos) = v.binary_search(&id) {
                v.remove(pos);
            }
        }
        if let Some(first) = path.links.first() {
            for l in &path.links {
                if matches!(self.links[l.0].kind, LinkKind::NicUplinkRx) {
                    let senders = &mut self.rx_senders[l.0];
                    if let Some(i) = senders.iter().position(|(s, _)| *s == first.0) {
                        senders[i].1 -= 1;
                        if senders[i].1 == 0 {
                            senders.remove(i);
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Component-scoped allocation
    // ------------------------------------------------------------------

    /// Connected component of the flow↔link graph reachable from `seeds`,
    /// walked over the persistent reverse index. Returns sorted flow ids
    /// (the deterministic allocation order) and sorted link indices.
    fn component(&mut self, seeds: &[LinkId]) -> (Vec<FlowId>, Vec<usize>) {
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            // u32 wrap: clear stale stamps once every 4B passes.
            self.link_stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp = 1;
        }
        let stamp = self.stamp;
        let mut links: Vec<usize> = Vec::new();
        let mut flow_ids: Vec<FlowId> = Vec::new();
        let mut seen = HashSet::new();
        let mut queue: Vec<usize> = Vec::new();
        for &LinkId(l) in seeds {
            if self.link_stamp[l] != stamp {
                self.link_stamp[l] = stamp;
                links.push(l);
                queue.push(l);
            }
        }
        while let Some(l) = queue.pop() {
            for &fid in &self.link_flows[l] {
                if !seen.insert(fid) {
                    continue;
                }
                flow_ids.push(fid);
                for &LinkId(fl) in &self.flows[&fid].path.links {
                    if self.link_stamp[fl] != stamp {
                        self.link_stamp[fl] = stamp;
                        links.push(fl);
                        queue.push(fl);
                    }
                }
            }
        }
        flow_ids.sort_unstable();
        links.sort_unstable();
        (flow_ids, links)
    }

    /// Recompute rates for the component touched by a change, apply them,
    /// and emit fresh timers. Flows outside the component are untouched.
    fn reallocate(&mut self, now: SimTime, seeds: &[LinkId]) -> Vec<FlowTimer> {
        self.alloc.changes += 1;
        self.alloc.global_floor += self.flows.len() as u64;

        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.force_global {
            let mut ids: Vec<FlowId> = self.flows.keys().copied().collect();
            ids.sort_unstable();
            self.alloc.max_component = self.alloc.max_component.max(ids.len() as u64);
            // Reference mode walks the whole net — the AllocPass payload
            // reports that honestly (see the TraceEvent doc).
            self.tracer.record(
                now,
                TraceEvent::AllocPass { flows: ids.len(), links: self.links.len() },
            );
            let (rates, visits) = self.reference_rates();
            self.alloc.flow_visits += visits;
            return self.apply_rates(now, &ids, &rates);
        }

        let (ids, comp_links) = self.component(seeds);
        self.alloc.max_component = self.alloc.max_component.max(ids.len() as u64);
        // Flight-recorder span of the allocator's locality: one record per
        // pass, folded into a component-size histogram by the Chrome
        // exporter. Pure observation — counters and rates are unaffected.
        self.tracer
            .record(now, TraceEvent::AllocPass { flows: ids.len(), links: comp_links.len() });
        let rates = self.waterfill(&ids, &comp_links);
        let timers = self.apply_rates(now, &ids, &rates);
        #[cfg(debug_assertions)]
        self.cross_check(&ids, &rates);
        timers
    }

    /// Max-min water filling over one component. Ids are SORTED: the
    /// allocation itself is order-independent, but the floating-point
    /// residual-capacity bookkeeping and the order timers (and trace
    /// records) are emitted are not — iterating in HashMap order would leak
    /// the per-process hasher seed into event tie-breaking and break the
    /// bit-identical trace contract (DESIGN.md, "Determinism contract").
    fn waterfill(&mut self, ids: &[FlowId], comp_links: &[usize]) -> HashMap<FlowId, f64> {
        // Effective capacity per component link: 0 when down; incast-
        // degraded on receive ports fed by multiple *distinct sender
        // ports* (count read off the persistent index). Chunks of one
        // sender share its egress serially and are not incast — only a
        // true many-to-one fan-in triggers PFC backpressure (§Appendix G
        // phase 2). `cap_scratch` then doubles as the residual capacity.
        for &l in comp_links {
            let st = &self.links[l];
            self.cap_scratch[l] = if !st.up {
                0.0
            } else {
                let n = self.rx_senders[l].len();
                if n > 1 && matches!(st.kind, LinkKind::NicUplinkRx) {
                    st.capacity_bpns / (1.0 + self.incast_penalty * (n - 1) as f64)
                } else {
                    st.capacity_bpns
                }
            };
        }
        let mut rate: HashMap<FlowId, f64> = HashMap::with_capacity(ids.len());
        let mut frozen: HashMap<FlowId, bool> = ids.iter().map(|&i| (i, false)).collect();
        // Flows crossing any dead link are stalled outright.
        for &id in ids {
            let f = &self.flows[&id];
            if f.path.links.iter().any(|l| self.cap_scratch[l.0] <= 0.0) {
                rate.insert(id, 0.0);
                frozen.insert(id, true);
            }
        }
        loop {
            // Count unfrozen flows per component link.
            for &l in comp_links {
                self.unfrozen_scratch[l] = 0;
            }
            let mut any_unfrozen = false;
            for &id in ids {
                self.alloc.flow_visits += 1;
                if frozen[&id] {
                    continue;
                }
                any_unfrozen = true;
                for l in &self.flows[&id].path.links {
                    self.unfrozen_scratch[l.0] += 1;
                }
            }
            if !any_unfrozen {
                break;
            }
            // Bottleneck link: minimal fair share (ties → lowest link id,
            // identical to the reference's ascending full-table scan).
            let mut best: Option<(usize, f64)> = None;
            for &i in comp_links {
                let n = self.unfrozen_scratch[i];
                if n == 0 {
                    continue;
                }
                let share = self.cap_scratch[i] / n as f64;
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((i, share));
                }
            }
            let Some((bottleneck, share)) = best else { break };
            // Freeze every unfrozen flow crossing the bottleneck at `share`.
            let freezing: Vec<FlowId> = ids
                .iter()
                .copied()
                .filter(|id| {
                    !frozen[id]
                        && self.flows[id].path.links.iter().any(|l| l.0 == bottleneck)
                })
                .collect();
            for id in freezing {
                rate.insert(id, share);
                frozen.insert(id, true);
                for l in &self.flows[&id].path.links {
                    self.cap_scratch[l.0] = (self.cap_scratch[l.0] - share).max(0.0);
                }
            }
        }
        rate
    }

    /// Apply freshly computed rates to `ids` (sorted), bump generations and
    /// emit timers — but ONLY for flows whose rate actually changed (>0.1%
    /// relative): an unchanged rate means the outstanding completion timer
    /// is still exact, and skipping the re-emit keeps untouched flows
    /// bit-identical across allocation modes (and removes the O(flows)
    /// stale-event storm per network change).
    fn apply_rates(
        &mut self,
        now: SimTime,
        ids: &[FlowId],
        rates: &HashMap<FlowId, f64>,
    ) -> Vec<FlowTimer> {
        let mut timers = Vec::with_capacity(ids.len());
        for &id in ids {
            self.alloc.flow_visits += 1;
            let f = self.flows.get_mut(&id).expect("component ids are current");
            let r = rates.get(&id).copied().unwrap_or(0.0);
            let old = f.rate_bpns;
            let unchanged = if old > 0.0 {
                (r - old).abs() <= 1e-3 * old
            } else {
                r <= 0.0
            };
            if unchanged {
                continue;
            }
            // Snapshot progress at the old rate before switching.
            f.materialize(now);
            // Trace only meaningful transitions: stall (>0 → 0 with bytes
            // left), resume (stalled → moving), and re-rates beyond 10 % —
            // the fair-share wobble every start/finish causes would
            // otherwise dominate the ring.
            if self.tracer.enabled() {
                if old > 0.0 && r <= 0.0 && f.remaining > 0.5 {
                    // Name the culprit: the first down link on the flow's
                    // path (None for a pure-contention stall). The RCA
                    // graph derives its Flow→Link→Port edges from this.
                    let link =
                        f.path.links.iter().find(|l| !self.links[l.0].up).map(|l| l.0);
                    self.tracer.record(now, TraceEvent::FlowStalled { flow: id.0, link });
                } else if old <= 0.0 && r > 0.0 && f.was_stalled {
                    self.tracer
                        .record(now, TraceEvent::FlowResumed { flow: id.0, scope: "flow" });
                } else if old > 0.0 && r > 0.0 && (r - old).abs() > 0.10 * old {
                    self.tracer.record(now, TraceEvent::FlowRerated { flow: id.0, gbps: r * 8.0 });
                }
            }
            if r <= 0.0 && old > 0.0 {
                f.was_stalled = true;
            } else if r > 0.0 {
                f.was_stalled = false;
            }
            f.rate_bpns = r;
            f.gen += 1;
            if let Some(drained) = f.drained_at {
                // Payload already drained: only the fixed tail is owed.
                // The deadline survives re-rates (and even stalls) at the
                // same absolute instant.
                let at = (drained + SimTime::ns(f.tail_latency_ns)).max(now);
                timers.push(FlowTimer { flow: id, gen: f.gen, at });
            } else if r > 0.0 {
                let eta_ns = (f.remaining / r).ceil() as u64 + f.tail_latency_ns;
                timers.push(FlowTimer { flow: id, gen: f.gen, at: now + SimTime::ns(eta_ns) });
            }
            // Stalled flows get no timer — the RDMA retry layer owns them.
        }
        timers
    }

    // ------------------------------------------------------------------
    // Reference allocator (the original global algorithm)
    // ------------------------------------------------------------------

    /// The pre-§Perf-L3 global allocator, kept verbatim as the reference
    /// implementation: recomputes distinct-sender counts from scratch and
    /// water-fills over **every** link and flow — O(links × flows) per
    /// change. Returns the ideal rate map plus the flows-examined count.
    /// Debug builds cross-check every incremental pass against it; enable
    /// the `ref-alloc` cargo feature to keep it in release builds (the
    /// `flownet` bench uses that for the measured work comparison).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    fn reference_rates(&self) -> (HashMap<FlowId, f64>, u64) {
        let mut visits = 0u64;
        let mut senders_per_link: HashMap<usize, Vec<usize>> = HashMap::new();
        for f in self.flows.values() {
            visits += 1;
            let Some(first) = f.path.links.first() else { continue };
            for l in &f.path.links {
                if matches!(self.links[l.0].kind, LinkKind::NicUplinkRx) {
                    let v = senders_per_link.entry(l.0).or_default();
                    if !v.contains(&first.0) {
                        v.push(first.0);
                    }
                }
            }
        }
        let eff_cap: Vec<f64> = self
            .links
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if !l.up {
                    return 0.0;
                }
                let n = senders_per_link.get(&i).map_or(0, |v| v.len());
                if n > 1 && matches!(l.kind, LinkKind::NicUplinkRx) {
                    l.capacity_bpns / (1.0 + self.incast_penalty * (n - 1) as f64)
                } else {
                    l.capacity_bpns
                }
            })
            .collect();

        let mut ids: Vec<FlowId> = self.flows.keys().copied().collect();
        ids.sort_unstable();
        let mut rate: HashMap<FlowId, f64> = HashMap::with_capacity(ids.len());
        let mut frozen: HashMap<FlowId, bool> = ids.iter().map(|&i| (i, false)).collect();
        for &id in &ids {
            let f = &self.flows[&id];
            if f.path.links.iter().any(|l| eff_cap[l.0] <= 0.0) {
                rate.insert(id, 0.0);
                frozen.insert(id, true);
            }
        }
        let mut remaining_cap = eff_cap;
        loop {
            let mut unfrozen_per_link = vec![0u32; self.links.len()];
            let mut any_unfrozen = false;
            for &id in &ids {
                visits += 1;
                if frozen[&id] {
                    continue;
                }
                any_unfrozen = true;
                for l in &self.flows[&id].path.links {
                    unfrozen_per_link[l.0] += 1;
                }
            }
            if !any_unfrozen {
                break;
            }
            let mut best: Option<(usize, f64)> = None;
            for (i, &n) in unfrozen_per_link.iter().enumerate() {
                if n == 0 {
                    continue;
                }
                let share = remaining_cap[i] / n as f64;
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((i, share));
                }
            }
            let Some((bottleneck, share)) = best else { break };
            let freezing: Vec<FlowId> = ids
                .iter()
                .copied()
                .filter(|id| {
                    !frozen[id]
                        && self.flows[id].path.links.iter().any(|l| l.0 == bottleneck)
                })
                .collect();
            for id in freezing {
                rate.insert(id, share);
                frozen.insert(id, true);
                for l in &self.flows[&id].path.links {
                    remaining_cap[l.0] = (remaining_cap[l.0] - share).max(0.0);
                }
            }
        }
        (rate, visits)
    }

    /// Debug-build invariant: the component-scoped result must match the
    /// global reference bit-for-bit inside the component, and every stored
    /// rate (including flows the pass never visited) must sit within the
    /// re-rate tolerance of the global ideal.
    #[cfg(debug_assertions)]
    fn cross_check(&self, ids: &[FlowId], scoped: &HashMap<FlowId, f64>) {
        if self.force_global {
            return;
        }
        let (global, _) = self.reference_rates();
        for &id in ids {
            let a = scoped.get(&id).copied().unwrap_or(0.0);
            let b = global.get(&id).copied().unwrap_or(0.0);
            debug_assert!(
                a.to_bits() == b.to_bits(),
                "component allocation diverged from the global reference for {id:?}: {a} vs {b}"
            );
        }
        for (&id, f) in &self.flows {
            let b = global.get(&id).copied().unwrap_or(0.0);
            let ok = if f.rate_bpns > 0.0 {
                (b - f.rate_bpns).abs() <= 1e-3 * f.rate_bpns
            } else {
                b <= 0.0
            };
            debug_assert!(
                ok,
                "stored rate drifted outside tolerance of the global ideal for {id:?}: \
                 stored {} vs ideal {b}",
                f.rate_bpns
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;
    use crate::topology::{NicId, NodeId, PortId};
    use crate::util::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    fn fabric() -> Fabric {
        Fabric::build(&TopologyConfig { num_nodes: 2, ..Default::default() })
    }

    fn port(node: usize, nic: usize) -> PortId {
        PortId { nic: NicId { node: NodeId(node), local: nic }, port: 0 }
    }

    /// Drive the net to completion, returning (time, meta) per finish.
    /// Heap-based (O(log n) per event): the randomized equivalence sweep
    /// pushes thousands of timers, and the old linear-scan-min + retain
    /// loop was O(n²).
    fn run_to_completion(net: &mut FlowNet, timers: Vec<FlowTimer>) -> Vec<(SimTime, FlowMeta)> {
        let mut queue: BinaryHeap<Reverse<(SimTime, u64, u32)>> =
            timers.iter().map(|t| Reverse((t.at, t.flow.0, t.gen))).collect();
        let mut done = Vec::new();
        while let Some(Reverse((at, flow, gen))) = queue.pop() {
            let (meta, more) = net.try_finish(FlowId(flow), gen, at);
            if let Some(m) = meta {
                done.push((at, m));
            }
            queue.extend(more.iter().map(|t| Reverse((t.at, t.flow.0, t.gen))));
        }
        done
    }

    #[test]
    fn single_flow_runs_at_line_rate() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let bytes = 64 * 1024 * 1024u64; // 64MB
        let (_, timers) = net.start(SimTime::ZERO, path, bytes, 0, FlowMeta(1));
        let done = run_to_completion(&mut net, timers);
        assert_eq!(done.len(), 1);
        // 64MB at 400Gbps = 50 GB/s → ≈1.342 ms
        let ms = done[0].0.as_ms_f64();
        assert!((ms - 1.342).abs() < 0.01, "ms={ms}");
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path1 = f.path_inter(port(0, 0), port(1, 0));
        let path2 = f.path_inter(port(0, 0), port(1, 0)); // same links
        let bytes = 8 * 1024 * 1024u64;
        let (_, mut t1) = net.start(SimTime::ZERO, path1, bytes, 0, FlowMeta(1));
        let (_, t2) = net.start(SimTime::ZERO, path2, bytes, 0, FlowMeta(2));
        t1.extend(t2);
        let done = run_to_completion(&mut net, t1);
        assert_eq!(done.len(), 2);
        // Both should finish at ≈2× the solo time (fair halves).
        let solo_ns = 8.0 * 1024.0 * 1024.0 / (400.0 * 0.125);
        for (at, _) in &done {
            let ratio = at.as_ns() as f64 / solo_ns;
            assert!((ratio - 2.0).abs() < 0.05, "ratio={ratio}");
        }
    }

    #[test]
    fn disjoint_flows_do_not_interact() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let bytes = 4 * 1024 * 1024u64;
        let (_, mut ts) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
        let (_, t2) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 1)), bytes, 0, FlowMeta(2));
        ts.extend(t2);
        let done = run_to_completion(&mut net, ts);
        let solo_ns = (4.0f64 * 1024.0 * 1024.0 / (400.0 * 0.125)).ceil();
        for (at, _) in &done {
            assert!((at.as_ns() as f64 - solo_ns).abs() < 10.0);
        }
    }

    /// Disjoint flows live in disjoint components: starting the second one
    /// must not visit (or re-rate) the first.
    #[test]
    fn disjoint_flows_are_separate_components() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let bytes = 4 << 20;
        let (_, _t1) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
        let (_, t2) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 1)), bytes, 0, FlowMeta(2));
        assert_eq!(t2.len(), 1, "only the new flow may be re-rated");
        assert_eq!(net.alloc_stats().max_component, 1);
        // A third flow sharing the first pair's links merges components.
        let (_, t3) =
            net.start(SimTime::ns(10), f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(3));
        assert_eq!(t3.len(), 2, "both flows of the shared component re-rate");
        assert_eq!(net.alloc_stats().max_component, 2);
    }

    #[test]
    fn link_down_stalls_and_up_resumes() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let bytes = 8 * 1024 * 1024u64;
        let (id, timers) = net.start(SimTime::ZERO, path, bytes, 0, FlowMeta(7));
        // Take the port down halfway through.
        let half = SimTime::ns(timers[0].at.as_ns() / 2);
        let tx = f.port_tx(port(0, 0));
        let t_down = net.set_link_up(tx, false, half);
        assert!(t_down.is_empty(), "stalled flow must get no timer");
        assert_eq!(net.is_stalled(id), Some(true));
        // Old timer is stale now.
        let (meta, _) = net.try_finish(id, timers[0].gen, timers[0].at);
        assert!(meta.is_none());
        // Bring it back at t=1ms; remaining half drains.
        let up_at = SimTime::ms(1);
        let t_up = net.set_link_up(tx, true, up_at);
        assert_eq!(t_up.len(), 1);
        let done = run_to_completion(&mut net, t_up);
        assert_eq!(done.len(), 1);
        let expect_ns = 1_000_000.0 + (bytes as f64 / 2.0) / (400.0 * 0.125);
        assert!((done[0].0.as_ns() as f64 - expect_ns).abs() < 100.0);
    }

    /// A physical port flap (tx + rx together) is one batched recompute.
    #[test]
    fn port_flap_batches_one_recompute() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let (_, _t) = net.start(
            SimTime::ZERO,
            f.path_inter(port(0, 0), port(1, 0)),
            8 << 20,
            0,
            FlowMeta(1),
        );
        let before = net.alloc_stats().changes;
        let links = f.port_links(port(0, 0));
        let _ = net.set_links_up(&links, false, SimTime::us(10));
        assert_eq!(net.alloc_stats().changes, before + 1, "one pass for both directions");
        assert!(!net.link_up(links[0]) && !net.link_up(links[1]));
    }

    #[test]
    fn tracer_records_stall_and_resume_transitions() {
        use crate::trace::{TraceSink, Tracer};
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let sink = TraceSink::new(1024, 1_000_000_000);
        net.set_tracer(Tracer::attached(sink.clone()));
        let path = f.path_inter(port(0, 0), port(1, 0));
        let (id, _) = net.start(SimTime::ZERO, path, 8 << 20, 0, FlowMeta(1));
        let tx = f.port_tx(port(0, 0));
        net.set_link_up(tx, false, SimTime::us(10));
        net.set_link_up(tx, true, SimTime::ms(1));
        let kinds: Vec<&str> = sink.records().iter().map(|r| r.ev.kind()).collect();
        let pos = |k: &str| kinds.iter().position(|x| *x == k);
        let started = pos("FlowStarted").expect("start recorded");
        let stalled = pos("FlowStalled").expect("stall recorded");
        let resumed = pos("FlowResumed").expect("resume recorded");
        assert!(started < stalled && stalled < resumed, "{kinds:?}");
        assert_eq!(net.is_stalled(id), Some(false));
    }

    #[test]
    fn incast_degrades_goodput_below_fair_share() {
        let f = fabric();
        // Two senders (node0 nic0, node0 nic1 → cross-rail) into ONE
        // receive port on node1 nic0.
        let mut fair = FlowNet::from_fabric(&f, 1.0, 0.0);
        let mut incast = FlowNet::from_fabric(&f, 1.0, 0.5);
        let bytes = 4 * 1024 * 1024u64;
        for net in [&mut fair, &mut incast] {
            let mut ts = Vec::new();
            let (_, t1) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
            let (_, t2) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 0)), bytes, 0, FlowMeta(2));
            ts.extend(t1);
            ts.extend(t2);
            let done = run_to_completion(net, ts);
            assert_eq!(done.len(), 2);
        }
        // With penalty 0.5 and 2 flows, effective receive capacity is
        // 400/(1.5) ≈ 267 Gbps vs 400 — re-run to compare finish times.
        let mut fair = FlowNet::from_fabric(&f, 1.0, 0.0);
        let mut slow = FlowNet::from_fabric(&f, 1.0, 0.5);
        let mut t_fair = SimTime::ZERO;
        let mut t_slow = SimTime::ZERO;
        for (net, out) in [(&mut fair, &mut t_fair), (&mut slow, &mut t_slow)] {
            let mut ts = Vec::new();
            let (_, t1) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
            let (_, t2) =
                net.start(SimTime::ZERO, f.path_inter(port(0, 1), port(1, 0)), bytes, 0, FlowMeta(2));
            ts.extend(t1);
            ts.extend(t2);
            let done = run_to_completion(net, ts);
            *out = done.iter().map(|(t, _)| *t).max().unwrap();
        }
        let ratio = t_slow.as_ns() as f64 / t_fair.as_ns() as f64;
        assert!((ratio - 1.5).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn tail_latency_added_once() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let (_, timers) = net.start(SimTime::ZERO, path, 1024, 5_000, FlowMeta(1));
        let done = run_to_completion(&mut net, timers);
        // 1KB at 400Gbps ≈ 20ns + 5000ns tail.
        let ns = done[0].0.as_ns();
        assert!((5_015..5_030).contains(&ns), "ns={ns}");
    }

    /// Regression (tail-fold bug): re-rating a flow mid-payload must not
    /// stretch its tail. The tail used to be folded into `remaining` as
    /// rate-equivalent bytes at the first rate, so a later rate drop
    /// stretched it proportionally.
    #[test]
    fn rerate_does_not_stretch_tail() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        let bytes = 8 * 1024 * 1024u64; // 8MiB at 50 B/ns → drains in ~167773ns
        let tail = 1_000_000u64; // 1ms tail — the old fold was 50MB of "bytes"
        let (_, mut ts) = net.start(SimTime::ZERO, path.clone(), bytes, tail, FlowMeta(1));
        // Halve A's rate at ~half drain by starting B on the same links.
        let half = SimTime::ns(83_886);
        let (_, t2) = net.start(half, path, bytes, 0, FlowMeta(2));
        ts.extend(t2);
        let done = run_to_completion(&mut net, ts);
        assert_eq!(done.len(), 2);
        let at = |m: u64| done.iter().find(|(_, meta)| meta.0 == m).unwrap().0.as_ns();
        // A: 4194308 bytes left at 25 B/ns → drains at ≈251659ns, plus the
        // UNSCALED 1ms tail. The old fold would have pushed this past 2.2ms.
        let a = at(1);
        assert!(
            (1_251_650..=1_251_670).contains(&a),
            "tail must not stretch under re-rate: a={a}"
        );
        // B drains alone after A's payload is done (A's share frees once A
        // is removed at its tail deadline; B finishes well before that).
        assert!(at(2) < a);
    }

    /// Regression (tail-fold bug, second shape): a re-rate AFTER the
    /// payload drained — during the tail wait — must not move the
    /// completion deadline at all.
    #[test]
    fn rerate_after_drain_keeps_tail_deadline() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let path = f.path_inter(port(0, 0), port(1, 0));
        // A: 1KB drains in ~21ns, then waits a 5μs tail.
        let (_, mut ts) = net.start(SimTime::ZERO, path.clone(), 1024, 5_000, FlowMeta(1));
        // B starts at t=1μs — A is drained but not complete; A gets
        // re-rated to the fair half. Its completion must stay ≈5021ns.
        let (_, t2) = net.start(SimTime::us(1), path, 8 << 20, 0, FlowMeta(2));
        ts.extend(t2);
        let done = run_to_completion(&mut net, ts);
        let a = done.iter().find(|(_, m)| m.0 == 1).unwrap().0.as_ns();
        assert!((5_015..5_030).contains(&a), "tail deadline moved: a={a}");
    }

    #[test]
    fn kill_removes_flow_and_rerates_survivors() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let bytes = 8 * 1024 * 1024u64;
        let (a, mut ts) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(1));
        let (_b, t2) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), bytes, 0, FlowMeta(2));
        ts.extend(t2);
        // Kill A at 25% of the shared schedule; B should then run at full rate.
        let kill_at = SimTime::ns(ts[0].at.as_ns() / 4);
        let mut timers = net.kill(a, kill_at);
        assert_eq!(net.active_flows(), 1);
        assert_eq!(timers.len(), 1);
        let done = run_to_completion(&mut net, std::mem::take(&mut timers));
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].1, FlowMeta(2));
    }

    /// Killing an already-gone flow is a constant-time no-op: no settle, no
    /// allocation pass (it used to pay a full O(flows) settle regardless).
    #[test]
    fn kill_missing_flow_is_noop() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let (a, _) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), 1 << 20, 0, FlowMeta(1));
        let _ = net.kill(a, SimTime::ns(10));
        let changes = net.alloc_stats().changes;
        assert!(net.kill(a, SimTime::ns(20)).is_empty());
        assert!(net.kill(FlowId(999), SimTime::ns(30)).is_empty());
        assert_eq!(net.alloc_stats().changes, changes, "no pass for a missing id");
    }

    #[test]
    fn stale_generation_ignored() {
        let f = fabric();
        let mut net = FlowNet::from_fabric(&f, 1.0, 0.0);
        let (id, t1) =
            net.start(SimTime::ZERO, f.path_inter(port(0, 0), port(1, 0)), 1 << 20, 0, FlowMeta(1));
        // Start a second flow → re-rates, bumping generation.
        let (_, _t2) =
            net.start(SimTime::ns(10), f.path_inter(port(0, 0), port(1, 0)), 1 << 20, 0, FlowMeta(2));
        let (meta, _) = net.try_finish(id, t1[0].gen, t1[0].at);
        assert!(meta.is_none(), "stale timer must not complete the flow");
    }

    // ------------------------------------------------------------------
    // Incremental vs reference equivalence
    // ------------------------------------------------------------------

    /// One op applied to both the incremental net and the reference-mode
    /// mirror; every mutating call must return identical timers.
    struct Mirror {
        inc: FlowNet,
        refn: FlowNet,
        live: Vec<FlowId>,
        queue: BinaryHeap<Reverse<(SimTime, u64, u32)>>,
    }

    impl Mirror {
        fn new(f: &Fabric) -> Self {
            let inc = FlowNet::from_fabric(f, 0.97, 0.35);
            let mut refn = FlowNet::from_fabric(f, 0.97, 0.35);
            refn.set_reference_mode(true);
            Mirror { inc, refn, live: Vec::new(), queue: BinaryHeap::new() }
        }

        fn push_timers(&mut self, ts: &[FlowTimer]) {
            self.queue.extend(ts.iter().map(|t| Reverse((t.at, t.flow.0, t.gen))));
        }

        fn check(&self, step: usize, a: &[FlowTimer], b: &[FlowTimer]) {
            assert_eq!(a, b, "step {step}: timers diverged");
            for &id in &self.live {
                let ra = self.inc.rate_gbps(id).map(f64::to_bits);
                let rb = self.refn.rate_gbps(id).map(f64::to_bits);
                assert_eq!(ra, rb, "step {step}: rate of {id:?} diverged");
                assert_eq!(
                    self.inc.is_stalled(id),
                    self.refn.is_stalled(id),
                    "step {step}: stall state of {id:?} diverged"
                );
            }
        }
    }

    /// The acceptance gate for §Perf L3: ~1k seeded random start / finish /
    /// kill / link-flap operations, with the incremental allocator's rates
    /// and timers asserted **bit-identical** to the reference global
    /// allocator at every step. (Debug builds additionally cross-check
    /// every pass inside `reallocate` itself.)
    #[test]
    fn randomized_equivalence_with_reference_allocator() {
        let f = Fabric::build(&TopologyConfig { num_nodes: 4, ..Default::default() });
        let mut m = Mirror::new(&f);
        let mut rng = Rng::new(0x51CA1E);
        let mut now = SimTime::ZERO;
        let mut next_meta = 0u64;
        // Track port states so flaps toggle coherently.
        let mut down_ports: Vec<PortId> = Vec::new();
        let ops = if cfg!(debug_assertions) { 400 } else { 1000 };
        for step in 0..ops {
            now = now + SimTime::ns(rng.range(1, 20_000));
            match rng.below(10) {
                // 0-4: fire the earliest pending completion timer.
                0..=4 if !m.queue.is_empty() => {
                    let Reverse((at, flow, gen)) = m.queue.pop().unwrap();
                    let fire_at = at.max(now);
                    now = fire_at;
                    let (ma, ta) = m.inc.try_finish(FlowId(flow), gen, fire_at);
                    let (mb, tb) = m.refn.try_finish(FlowId(flow), gen, fire_at);
                    assert_eq!(ma, mb, "step {step}: finish verdict diverged");
                    if ma.is_some() {
                        m.live.retain(|&i| i != FlowId(flow));
                    }
                    m.check(step, &ta, &tb);
                    m.push_timers(&ta);
                }
                // 5-6 (plus 0-4 while no timer is pending): start a flow
                // on a random inter-node path (same- or cross-rail).
                0..=6 => {
                    let nodes = 4;
                    let src = rng.below(nodes) as usize;
                    let mut dst = rng.below(nodes) as usize;
                    if dst == src {
                        dst = (dst + 1) % nodes as usize;
                    }
                    let path = f.path_inter(
                        port(src, rng.below(8) as usize),
                        port(dst, rng.below(8) as usize),
                    );
                    let bytes = rng.range(1 << 10, 4 << 20);
                    let tail = rng.range(0, 10_000);
                    next_meta += 1;
                    let (ia, ta) =
                        m.inc.start(now, path.clone(), bytes, tail, FlowMeta(next_meta));
                    let (ib, tb) = m.refn.start(now, path, bytes, tail, FlowMeta(next_meta));
                    assert_eq!(ia, ib, "step {step}: flow ids diverged");
                    m.live.push(ia);
                    m.check(step, &ta, &tb);
                    m.push_timers(&ta);
                }
                // 7: kill a random live flow.
                7 if !m.live.is_empty() => {
                    let id = m.live[rng.below(m.live.len() as u64) as usize];
                    m.live.retain(|&i| i != id);
                    let ta = m.inc.kill(id, now);
                    let tb = m.refn.kill(id, now);
                    m.check(step, &ta, &tb);
                    m.push_timers(&ta);
                }
                // 8-9: flap a port (batched tx+rx, like the RDMA layer).
                _ => {
                    if !down_ports.is_empty() && rng.chance(0.6) {
                        let p = down_ports.remove(rng.below(down_ports.len() as u64) as usize);
                        let links = f.port_links(p);
                        let ta = m.inc.set_links_up(&links, true, now);
                        let tb = m.refn.set_links_up(&links, true, now);
                        m.check(step, &ta, &tb);
                        m.push_timers(&ta);
                    } else {
                        let p = port(rng.below(4) as usize, rng.below(8) as usize);
                        if !down_ports.contains(&p) {
                            down_ports.push(p);
                            let links = f.port_links(p);
                            let ta = m.inc.set_links_up(&links, false, now);
                            let tb = m.refn.set_links_up(&links, false, now);
                            m.check(step, &ta, &tb);
                            m.push_timers(&ta);
                        }
                    }
                }
            }
            assert_eq!(
                m.inc.active_flows(),
                m.refn.active_flows(),
                "step {step}: live-flow sets diverged"
            );
        }
        // The workload must have actually exercised the incremental path.
        let a = m.inc.alloc_stats();
        assert!(a.changes as usize > ops / 3, "changes={}", a.changes);
        assert!(
            a.flow_visits < m.refn.alloc_stats().flow_visits,
            "incremental must do less work than the reference: {} vs {}",
            a.flow_visits,
            m.refn.alloc_stats().flow_visits
        );
    }
}
