//! Byte-size and bandwidth units.
//!
//! The simulator works internally in **bytes** and **nanoseconds**; these
//! wrappers keep conversions explicit and provide the human-readable
//! formatting used by the experiment reports (GB/s in the paper's figures,
//! Gbps on the wire).

use std::fmt;

/// A number of bytes, with convenience constructors mirroring the message
/// sizes NCCL-Tests sweeps (1KB .. 4GB).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ByteSize(pub u64);

impl ByteSize {
    pub const fn b(n: u64) -> Self {
        ByteSize(n)
    }
    pub const fn kb(n: u64) -> Self {
        ByteSize(n * 1024)
    }
    pub const fn mb(n: u64) -> Self {
        ByteSize(n * 1024 * 1024)
    }
    pub const fn gb(n: u64) -> Self {
        ByteSize(n * 1024 * 1024 * 1024)
    }
    pub fn as_f64(&self) -> f64 {
        self.0 as f64
    }
}

impl fmt::Display for ByteSize {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0 as f64;
        if self.0 >= 1 << 30 {
            write!(f, "{:.1}GB", b / (1u64 << 30) as f64)
        } else if self.0 >= 1 << 20 {
            write!(f, "{:.1}MB", b / (1u64 << 20) as f64)
        } else if self.0 >= 1 << 10 {
            write!(f, "{:.1}KB", b / (1u64 << 10) as f64)
        } else {
            write!(f, "{}B", self.0)
        }
    }
}

/// Bandwidth in gigabits per second (the unit the paper's figures use for
/// link and collective throughput).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Gbps(pub f64);

impl Gbps {
    /// Bytes per nanosecond: 1 Gbps = 1e9 bit/s = 0.125 B/ns.
    pub fn bytes_per_ns(&self) -> f64 {
        self.0 * 0.125
    }

    /// Construct from a transfer of `bytes` over `ns` nanoseconds.
    pub fn from_transfer(bytes: u64, ns: u64) -> Gbps {
        if ns == 0 {
            return Gbps(f64::INFINITY);
        }
        Gbps(bytes as f64 / ns as f64 / 0.125)
    }

    /// Time in ns to move `bytes` at this rate.
    pub fn transfer_ns(&self, bytes: u64) -> u64 {
        if self.0 <= 0.0 {
            return u64::MAX;
        }
        (bytes as f64 / self.bytes_per_ns()).ceil() as u64
    }

    /// GB/s (the unit NCCL-Tests reports as busbw/algbw).
    pub fn gbytes_per_sec(&self) -> f64 {
        self.0 / 8.0
    }
}

impl fmt::Display for Gbps {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1}Gbps", self.0)
    }
}

/// Pretty-print a nanosecond duration (μs/ms/s auto-scaled).
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_size_constructors() {
        assert_eq!(ByteSize::kb(4).0, 4096);
        assert_eq!(ByteSize::mb(1).0, 1 << 20);
        assert_eq!(ByteSize::gb(4).0, 4u64 << 30);
    }

    #[test]
    fn byte_size_display() {
        assert_eq!(ByteSize::b(100).to_string(), "100B");
        assert_eq!(ByteSize::kb(2).to_string(), "2.0KB");
        assert_eq!(ByteSize::mb(32).to_string(), "32.0MB");
    }

    #[test]
    fn gbps_round_trip() {
        // 400 Gbps moves 50 GB/s → 1 MB in ~20.97us.
        let bw = Gbps(400.0);
        let ns = bw.transfer_ns(1 << 20);
        assert!((ns as f64 - 20_971.52).abs() < 2.0, "ns={ns}");
        let back = Gbps::from_transfer(1 << 20, ns);
        assert!((back.0 - 400.0).abs() < 0.1);
    }

    #[test]
    fn gbps_gbytes() {
        assert!((Gbps(400.0).gbytes_per_sec() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500), "500ns");
        assert_eq!(fmt_ns(1_500), "1.500us");
        assert_eq!(fmt_ns(2_500_000), "2.500ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000s");
    }

    #[test]
    fn zero_rate_never_finishes() {
        assert_eq!(Gbps(0.0).transfer_ns(1), u64::MAX);
    }
}
