//! Deterministic pseudo-random number generator (xoshiro256**).
//!
//! Every stochastic element of the simulation (failure injection, jitter,
//! synthetic workloads) draws from an explicitly seeded [`Rng`] so that
//! experiments are bit-for-bit reproducible — a hard requirement for the
//! regression-style experiment harness (`vccl exp ...`).

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n). Unbiased via rejection (Lemire-ish fallback).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Simple modulo with rejection of the biased tail.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with the given mean.
    /// Used for failure inter-arrival times (link flaps are a Poisson-ish
    /// process in the paper's Fig 2 failure statistics).
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0,1]
        -mean * u.ln()
    }

    /// Normally distributed sample (Box–Muller), for compute-time jitter.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal-ish positive jitter multiplier centred on 1.0.
    pub fn jitter(&mut self, rel_std: f64) -> f64 {
        (self.normal(0.0, rel_std)).exp()
    }

    /// Fork an independent child stream (for per-entity RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// The raw 256-bit generator state (§Soak checkpointing). Together with
    /// [`Rng::from_state`] this round-trips the stream exactly: a restored
    /// generator continues the identical sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a previously captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_roughly_correct() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_stats_roughly_correct() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.1, "std={}", var.sqrt());
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_continues_the_stream() {
        let mut a = Rng::new(0x5CC1);
        for _ in 0..100 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 3);
    }
}
