//! Small utilities shared across the simulator: deterministic RNG, byte /
//! bandwidth units, and human-readable formatting.

pub mod ckpt;
pub mod rng;
pub mod units;

pub use ckpt::{fingerprint, CkptReader, CkptWriter};
pub use rng::Rng;
pub use units::{ByteSize, Gbps};
