//! Small utilities shared across the simulator: deterministic RNG, byte /
//! bandwidth units, and human-readable formatting.

pub mod rng;
pub mod units;

pub use rng::Rng;
pub use units::{ByteSize, Gbps};
