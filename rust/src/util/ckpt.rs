//! Checkpoint token codec (§Soak): a hand-rolled, versioned, whitespace-
//! separated token format for simulation-state snapshots.
//!
//! Design constraints, in order:
//!
//! 1. **Bit-exactness.** A resumed simulation must be indistinguishable
//!    from one that never stopped, so every value round-trips exactly:
//!    `f64`s are written as the hex of their IEEE-754 bits (never decimal),
//!    integers in plain decimal, booleans as `0`/`1`.
//! 2. **Self-description.** Every field is preceded by a tag token and the
//!    reader demands the tag back (`expect`), so a writer/reader skew fails
//!    loudly at the first divergent field instead of silently misparsing
//!    the rest of the stream — the same "no silent misconfig" stance as
//!    `Config::set_key`.
//! 3. **No arbitrary strings.** Tokens never contain whitespace; enums are
//!    serialized as short tag tokens. That keeps the grammar trivial
//!    (`split_ascii_whitespace`) and the files diffable.
//!
//! The format carries a magic + version header (`VCCLCKPT v1 ...`) and a
//! config fingerprint; see `ClusterSim::checkpoint` for the layout and
//! DESIGN.md §Soak for the compatibility contract (a version bump is
//! REQUIRED whenever any serialized structure changes shape).

use std::fmt::Write as _;

/// Streaming writer: tokens separated by single spaces, one logical record
/// per `section` line break (cosmetic only — the reader treats the whole
/// file as one token stream).
#[derive(Debug)]
pub struct CkptWriter {
    buf: String,
}

impl CkptWriter {
    /// Start a checkpoint stream with a magic token and format version.
    pub fn new(magic: &str, version: u32) -> Self {
        let mut w = CkptWriter { buf: String::with_capacity(4096) };
        w.token(magic);
        w.token(&format!("v{version}"));
        w
    }

    /// Append a bare token (must contain no whitespace).
    pub fn token(&mut self, t: &str) {
        debug_assert!(!t.is_empty() && !t.chars().any(|c| c.is_whitespace()), "bad token {t:?}");
        if !self.buf.is_empty() {
            self.buf.push(' ');
        }
        self.buf.push_str(t);
    }

    /// Cosmetic line break before a named section tag.
    pub fn section(&mut self, name: &str) {
        self.buf.push('\n');
        self.buf.push_str(name);
    }

    /// `tag value` pair for a u64.
    pub fn u64(&mut self, tag: &str, v: u64) {
        self.token(tag);
        let _ = write!(self.buf, " {v}");
    }

    pub fn u32(&mut self, tag: &str, v: u32) {
        self.u64(tag, v as u64);
    }

    pub fn usize(&mut self, tag: &str, v: usize) {
        self.u64(tag, v as u64);
    }

    pub fn bool(&mut self, tag: &str, v: bool) {
        self.u64(tag, v as u64);
    }

    /// `tag value` pair for an f64, written as hex bits: exact round-trip.
    pub fn f64(&mut self, tag: &str, v: f64) {
        self.token(tag);
        let _ = write!(self.buf, " {:016x}", v.to_bits());
    }

    /// `tag 0` / `tag 1 value` for an optional u64.
    pub fn opt_u64(&mut self, tag: &str, v: Option<u64>) {
        self.token(tag);
        match v {
            None => self.buf.push_str(" 0"),
            Some(x) => {
                let _ = write!(self.buf, " 1 {x}");
            }
        }
    }

    pub fn finish(self) -> String {
        let mut s = self.buf;
        s.push('\n');
        s
    }
}

/// Pull-parser over the token stream. Every accessor returns a `Result`
/// with a message naming the expected tag, so a truncated or skewed
/// checkpoint reports *where* it diverged.
pub struct CkptReader<'a> {
    toks: std::str::SplitAsciiWhitespace<'a>,
}

impl<'a> CkptReader<'a> {
    /// Open a stream, checking the magic and version header.
    pub fn new(text: &'a str, magic: &str, version: u32) -> Result<Self, String> {
        let mut r = CkptReader { toks: text.split_ascii_whitespace() };
        let m = r.next_tok("magic")?;
        if m != magic {
            return Err(format!("bad magic: expected {magic:?}, found {m:?}"));
        }
        let v = r.next_tok("version")?;
        let want = format!("v{version}");
        if v != want {
            return Err(format!("unsupported checkpoint version {v:?} (this build reads {want})"));
        }
        Ok(r)
    }

    fn next_tok(&mut self, what: &str) -> Result<&'a str, String> {
        self.toks.next().ok_or_else(|| format!("truncated checkpoint: expected {what}"))
    }

    /// Demand the next token to be exactly `tag`.
    pub fn expect(&mut self, tag: &str) -> Result<(), String> {
        let t = self.next_tok(tag)?;
        if t != tag {
            return Err(format!("expected tag {tag:?}, found {t:?}"));
        }
        Ok(())
    }

    /// Read a bare token (enum discriminants, section names chosen by the
    /// caller).
    pub fn token(&mut self) -> Result<&'a str, String> {
        self.next_tok("a token")
    }

    pub fn u64(&mut self, tag: &str) -> Result<u64, String> {
        self.expect(tag)?;
        let t = self.next_tok(tag)?;
        t.parse::<u64>().map_err(|e| format!("bad u64 for {tag:?}: {t:?} ({e})"))
    }

    pub fn u32(&mut self, tag: &str) -> Result<u32, String> {
        let v = self.u64(tag)?;
        u32::try_from(v).map_err(|_| format!("u32 overflow for {tag:?}: {v}"))
    }

    pub fn usize(&mut self, tag: &str) -> Result<usize, String> {
        let v = self.u64(tag)?;
        usize::try_from(v).map_err(|_| format!("usize overflow for {tag:?}: {v}"))
    }

    pub fn bool(&mut self, tag: &str) -> Result<bool, String> {
        match self.u64(tag)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("bad bool for {tag:?}: {v}")),
        }
    }

    pub fn f64(&mut self, tag: &str) -> Result<f64, String> {
        self.expect(tag)?;
        let t = self.next_tok(tag)?;
        let bits = u64::from_str_radix(t, 16)
            .map_err(|e| format!("bad f64 bits for {tag:?}: {t:?} ({e})"))?;
        Ok(f64::from_bits(bits))
    }

    pub fn opt_u64(&mut self, tag: &str) -> Result<Option<u64>, String> {
        self.expect(tag)?;
        let flag = self.next_tok(tag)?;
        match flag {
            "0" => Ok(None),
            "1" => {
                let t = self.next_tok(tag)?;
                t.parse::<u64>()
                    .map(Some)
                    .map_err(|e| format!("bad u64 for {tag:?}: {t:?} ({e})"))
            }
            other => Err(format!("bad option flag for {tag:?}: {other:?}")),
        }
    }

    /// Demand the stream to be fully consumed.
    pub fn finish(mut self) -> Result<(), String> {
        match self.toks.next() {
            None => Ok(()),
            Some(t) => Err(format!("trailing data in checkpoint: {t:?}")),
        }
    }
}

/// FNV-1a over a byte string — the config-fingerprint hash. Not
/// cryptographic; it only needs to catch "resumed under a different
/// config" mistakes deterministically.
pub fn fingerprint(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_all_field_kinds() {
        let mut w = CkptWriter::new("TESTCKPT", 1);
        w.section("nums");
        w.u64("a", u64::MAX);
        w.u32("b", 7);
        w.bool("c", true);
        w.f64("pi", std::f64::consts::PI);
        w.f64("nneg", -0.0);
        w.opt_u64("none", None);
        w.opt_u64("some", Some(42));
        w.token("enumtag");
        let text = w.finish();

        let mut r = CkptReader::new(&text, "TESTCKPT", 1).unwrap();
        assert_eq!(r.u64("a").unwrap(), u64::MAX);
        assert_eq!(r.u32("b").unwrap(), 7);
        // The section tag is a plain token in the stream.
        // (It was written before the fields — consume order must match.)
        let mut r = CkptReader::new(&text, "TESTCKPT", 1).unwrap();
        assert_eq!(r.token().unwrap(), "nums");
        assert_eq!(r.u64("a").unwrap(), u64::MAX);
        assert_eq!(r.u32("b").unwrap(), 7);
        assert!(r.bool("c").unwrap());
        assert_eq!(r.f64("pi").unwrap().to_bits(), std::f64::consts::PI.to_bits());
        assert_eq!(r.f64("nneg").unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.opt_u64("none").unwrap(), None);
        assert_eq!(r.opt_u64("some").unwrap(), Some(42));
        assert_eq!(r.token().unwrap(), "enumtag");
        r.finish().unwrap();
    }

    #[test]
    fn f64_bits_are_exact_for_nasty_values() {
        for v in [f64::MIN_POSITIVE, f64::EPSILON, 1.0 / 3.0, 1e-308, 2.2250738585072011e-308] {
            let mut w = CkptWriter::new("T", 1);
            w.f64("x", v);
            let text = w.finish();
            let mut r = CkptReader::new(&text, "T", 1).unwrap();
            assert_eq!(r.f64("x").unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn skew_and_truncation_fail_loudly() {
        let mut w = CkptWriter::new("T", 1);
        w.u64("a", 1);
        let text = w.finish();
        let mut r = CkptReader::new(&text, "T", 1).unwrap();
        assert!(r.u64("b").unwrap_err().contains("expected tag"));
        let mut r = CkptReader::new(&text, "T", 1).unwrap();
        let _ = r.u64("a").unwrap();
        assert!(r.u64("more").unwrap_err().contains("truncated"));
        assert!(CkptReader::new(&text, "OTHER", 1).unwrap_err().contains("magic"));
        assert!(CkptReader::new(&text, "T", 2).unwrap_err().contains("version"));
    }

    #[test]
    fn unconsumed_trailing_data_is_an_error() {
        let mut w = CkptWriter::new("T", 1);
        w.u64("a", 1);
        w.u64("b", 2);
        let text = w.finish();
        let mut r = CkptReader::new(&text, "T", 1).unwrap();
        let _ = r.u64("a").unwrap();
        assert!(r.finish().unwrap_err().contains("trailing"));
    }

    #[test]
    fn fingerprint_is_stable_and_discriminating() {
        assert_eq!(fingerprint("abc"), fingerprint("abc"));
        assert_ne!(fingerprint("abc"), fingerprint("abd"));
        assert_ne!(fingerprint(""), fingerprint(" "));
    }
}
