//! Simulated time: a nanosecond-resolution virtual clock.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time, in nanoseconds since simulation start.
///
/// Nanoseconds are the natural resolution for this paper: the monitor
/// operates at O(μs), RDMA WR→WC round trips are single-digit μs, and the
/// GPU-CPU synchronization costs the SM-free design removes are sub-μs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub const fn ns(n: u64) -> Self {
        SimTime(n)
    }
    pub const fn us(n: u64) -> Self {
        SimTime(n * 1_000)
    }
    pub const fn ms(n: u64) -> Self {
        SimTime(n * 1_000_000)
    }
    pub const fn s(n: u64) -> Self {
        SimTime(n * 1_000_000_000)
    }
    /// From fractional seconds (convenience for config values).
    pub fn from_secs_f64(s: f64) -> Self {
        SimTime((s * 1e9).round() as u64)
    }

    pub fn as_ns(&self) -> u64 {
        self.0
    }
    pub fn as_us_f64(&self) -> f64 {
        self.0 as f64 / 1e3
    }
    pub fn as_ms_f64(&self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn as_secs_f64(&self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Saturating difference (durations are non-negative).
    pub fn since(&self, earlier: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(earlier.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::units::fmt_ns(self.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        assert_eq!(SimTime::us(3).as_ns(), 3_000);
        assert_eq!(SimTime::ms(2).as_ns(), 2_000_000);
        assert_eq!(SimTime::s(1).as_ns(), 1_000_000_000);
        assert_eq!(SimTime::from_secs_f64(0.5).as_ns(), 500_000_000);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::us(10);
        let b = SimTime::us(4);
        assert_eq!((a + b).as_ns(), 14_000);
        assert_eq!((a - b).as_ns(), 6_000);
        // saturating
        assert_eq!((b - a).as_ns(), 0);
        assert_eq!(b.since(a).as_ns(), 0);
        assert_eq!(a.since(b).as_ns(), 6_000);
    }

    #[test]
    fn display_scales() {
        assert_eq!(SimTime::ns(12).to_string(), "12ns");
        assert_eq!(SimTime::us(9).to_string(), "9.000us");
    }

    #[test]
    fn ordering() {
        assert!(SimTime::ns(1) < SimTime::us(1));
        assert!(SimTime::s(1) > SimTime::ms(999));
    }
}
