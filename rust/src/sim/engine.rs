//! The event engine: a time-ordered queue of typed events with cancellation.
//!
//! The engine is *not* an actor framework — event payloads are a plain enum
//! owned by the simulation (`ClusterSim` dispatches them in one big match).
//! That keeps the hot loop branch-predictable and allocation-free, which is
//! what lets cluster-scale experiments (thousands of ranks × millions of
//! chunks) run in seconds. See `benches/simcore.rs` for the events/sec
//! target (§Perf L6: ≥1M events/s, CI-gated via `BENCH_simcore.json`).
//!
//! # §Perf L6 scheduler
//!
//! The default backend is a **calendar queue**: a power-of-two ring of
//! unsorted buckets, each covering one `bucket_ns`-wide slice of the clock,
//! plus an overflow heap for events beyond the ring's one-"day" coverage.
//! Only the bucket currently being drained is sorted (once, when the window
//! reaches it), so an event pays an amortized O(bucket) sort share instead
//! of the O(log n) sift of a multi-million-entry binary heap — and
//! same-instant bursts (a 4096-rank step issuing its chunk events) append
//! to the active window in O(1). When the ring goes empty the window
//! *jumps* straight to the earliest overflow event, so idle gaps (soak
//! bursts hours apart) cost O(1), not O(gap / bucket).
//!
//! The pre-L6 `BinaryHeap` survives as a cross-checked **reference mode**
//! (`set_reference_mode`, gated like the §Perf L3–L5 reference paths): the
//! randomized equivalence tests drive both backends through identical
//! trajectories and assert bit-identical pop sequences, and debug builds
//! additionally shadow every calendar operation with a key-only heap,
//! asserting each physical pop against it.
//!
//! # Cancellation accounting
//!
//! `live` and `cancelled` are disjoint seq sets partitioning the queued
//! entries: an event is in exactly one of them from `schedule` until its
//! slot is physically popped. `cancel` moves a seq live→cancelled only if
//! it is still live, so cancelling an already-fired (or already-cancelled)
//! id is an exact no-op: `pending()` stays exact and the tombstone set is
//! bounded by the entries physically queued — it cannot leak across a
//! multi-day soak (the regression test in `tests/soak.rs` pins this).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet, VecDeque};

use super::SimTime;

/// Handle to a scheduled event, usable to cancel it before it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

/// Default calendar bucket width. ~4 µs covers the per-chunk event spacing
/// of the cluster sim (NIC latencies + µs-scale chunk serialization);
/// retry windows, warm-ups and δ-probe periods (≥ milliseconds) land in
/// the overflow heap, which is exactly where rarely-touched events belong.
pub const DEFAULT_BUCKET_NS: u64 = 4_096;

/// Calendar ring size (one "day" = `NBUCKETS × bucket_ns` ≈ 4.2 ms at the
/// default width).
const NBUCKETS: usize = 1_024;

#[derive(Debug)]
struct Scheduled<Ev> {
    at: SimTime,
    seq: u64,
    ev: Ev,
}

// Order by (time, seq): seq breaks ties FIFO so simultaneous events fire in
// scheduling order — crucial for determinism.
impl<Ev> PartialEq for Scheduled<Ev> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<Ev> Eq for Scheduled<Ev> {}
impl<Ev> PartialOrd for Scheduled<Ev> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<Ev> Ord for Scheduled<Ev> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// §Perf L6 scheduler work counters. All are deterministic functions of
/// the event trajectory — safe to ship in `BENCH_simcore.json`, unlike
/// wall-clock time.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineStats {
    /// Events dispatched so far.
    pub dispatched: u64,
    /// Live (schedulable, un-cancelled) events currently pending.
    pub pending: usize,
    /// High-water mark of `pending`.
    pub peak_pending: usize,
    /// Cancelled entries still physically queued (tombstone backlog —
    /// bounded by the queue, never by cancellation history).
    pub cancelled_backlog: usize,
    /// Calendar: active-window materializations (one bucket sort each).
    pub window_sorts: u64,
    /// Calendar: events migrated overflow → ring as coverage advanced.
    pub overflow_pulls: u64,
    /// Calendar: empty-ring jumps straight to the earliest overflow event.
    pub window_jumps: u64,
}

/// The §Perf L6 calendar queue: a ring of unsorted buckets covering
/// `[win_end - bucket_ns, cov_end)`, one sorted active window, and an
/// overflow heap for everything at or beyond `cov_end`.
///
/// Invariants (cross-checked per pop by the engine's debug shadow heap):
/// - `active` is sorted ascending by `(at, seq)` and precedes every
///   bucket/overflow entry.
/// - an entry in `buckets[i]` has `win_end <= at < cov_end` and
///   `(at >> shift) & mask == i`; coverage is exactly one day, so each
///   bucket holds entries of a single window.
/// - `overflow` holds exactly the entries with `at >= cov_end`.
/// - `len` counts all queued entries (active + buckets + overflow).
#[derive(Debug)]
struct Calendar<Ev> {
    shift: u32,
    mask: usize,
    bucket_ns: u64,
    /// One day of coverage: `NBUCKETS << shift` nanoseconds.
    day: u64,
    /// Exclusive upper bound of the active window.
    win_end: u64,
    /// Exclusive upper bound of ring coverage.
    cov_end: u64,
    /// Ring index of the active window's bucket.
    cur: usize,
    buckets: Vec<Vec<Scheduled<Ev>>>,
    /// Entries across all buckets (excluding `active` and `overflow`).
    in_buckets: usize,
    active: VecDeque<Scheduled<Ev>>,
    overflow: BinaryHeap<Reverse<Scheduled<Ev>>>,
    len: usize,
    window_sorts: u64,
    overflow_pulls: u64,
    window_jumps: u64,
}

impl<Ev> Calendar<Ev> {
    fn new(bucket_ns: u64) -> Self {
        let bucket_ns = bucket_ns.clamp(64, 1 << 20).next_power_of_two();
        let shift = bucket_ns.trailing_zeros();
        let day = (NBUCKETS as u64) << shift;
        Calendar {
            shift,
            mask: NBUCKETS - 1,
            bucket_ns,
            day,
            win_end: bucket_ns,
            cov_end: day,
            cur: 0,
            buckets: (0..NBUCKETS).map(|_| Vec::new()).collect(),
            in_buckets: 0,
            active: VecDeque::new(),
            overflow: BinaryHeap::new(),
            len: 0,
            window_sorts: 0,
            overflow_pulls: 0,
            window_jumps: 0,
        }
    }

    #[inline]
    fn slot(&self, at: u64) -> usize {
        ((at >> self.shift) as usize) & self.mask
    }

    fn insert(&mut self, s: Scheduled<Ev>) {
        let at = s.at.as_ns();
        self.len += 1;
        if at < self.win_end {
            // In (or before) the already-materialized window: keep `active`
            // sorted. A fresh seq is the largest key among equal times, so
            // same-instant bursts scheduled while draining append at the
            // back — O(1), not a quadratic mid-insert.
            let key = (s.at, s.seq);
            let idx = self.active.partition_point(|e| (e.at, e.seq) < key);
            self.active.insert(idx, s);
        } else if at < self.cov_end {
            let slot = self.slot(at);
            self.in_buckets += 1;
            self.buckets[slot].push(s);
        } else {
            self.overflow.push(Reverse(s));
        }
    }

    /// Move overflow entries that fell inside coverage into their buckets.
    fn pull_overflow(&mut self) {
        while let Some(Reverse(s)) = self.overflow.peek() {
            if s.at.as_ns() >= self.cov_end {
                break;
            }
            let Reverse(s) = self.overflow.pop().expect("peeked");
            let slot = self.slot(s.at.as_ns());
            self.in_buckets += 1;
            self.buckets[slot].push(s);
            self.overflow_pulls += 1;
        }
    }

    /// Advance to the next non-empty window and sort it into `active`.
    /// Precondition: `active` is drained and `len > 0`.
    fn advance_window(&mut self) {
        debug_assert!(self.active.is_empty());
        debug_assert!(self.len > 0, "advance_window on an empty calendar");
        loop {
            if self.in_buckets == 0 {
                // Ring empty: everything pending sits in overflow. Jump the
                // window straight to the earliest event — an hours-long
                // soak idle gap costs O(1), not O(gap / bucket_ns).
                let min_at = {
                    let Reverse(s) = self.overflow.peek().expect("len > 0 with empty ring");
                    s.at.as_ns()
                };
                let win_start = min_at & !(self.bucket_ns - 1);
                self.win_end = win_start + self.bucket_ns;
                self.cov_end = win_start + self.day;
                self.cur = self.slot(win_start);
                self.window_jumps += 1;
                self.pull_overflow();
                debug_assert!(!self.buckets[self.cur].is_empty());
            } else {
                self.cur = (self.cur + 1) & self.mask;
                self.win_end += self.bucket_ns;
                self.cov_end += self.bucket_ns;
                self.pull_overflow();
            }
            if !self.buckets[self.cur].is_empty() {
                let mut bucket = std::mem::take(&mut self.buckets[self.cur]);
                self.in_buckets -= bucket.len();
                bucket.sort_unstable_by_key(|s| (s.at, s.seq));
                self.active = VecDeque::from(bucket);
                self.window_sorts += 1;
                return;
            }
        }
    }

    fn pop_min(&mut self) -> Option<Scheduled<Ev>> {
        loop {
            if let Some(s) = self.active.pop_front() {
                self.len -= 1;
                return Some(s);
            }
            if self.len == 0 {
                return None;
            }
            self.advance_window();
        }
    }

    /// Key of the earliest entry (materializes its window, consumes nothing).
    fn peek_min(&mut self) -> Option<(SimTime, u64)> {
        loop {
            if let Some(s) = self.active.front() {
                return Some((s.at, s.seq));
            }
            if self.len == 0 {
                return None;
            }
            self.advance_window();
        }
    }
}

/// The queue backend: calendar by default, the pre-L6 binary heap as the
/// cross-checked reference (gated like the §Perf L3–L5 reference paths).
#[derive(Debug)]
enum Backend<Ev> {
    Calendar(Calendar<Ev>),
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    Heap(BinaryHeap<Reverse<Scheduled<Ev>>>),
}

impl<Ev> Backend<Ev> {
    fn insert(&mut self, s: Scheduled<Ev>) {
        match self {
            Backend::Calendar(c) => c.insert(s),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            Backend::Heap(h) => h.push(Reverse(s)),
        }
    }

    fn pop_min(&mut self) -> Option<Scheduled<Ev>> {
        match self {
            Backend::Calendar(c) => c.pop_min(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            Backend::Heap(h) => h.pop().map(|Reverse(s)| s),
        }
    }

    fn peek_min(&mut self) -> Option<(SimTime, u64)> {
        match self {
            Backend::Calendar(c) => c.peek_min(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            Backend::Heap(h) => h.peek().map(|Reverse(s)| (s.at, s.seq)),
        }
    }

    fn queued(&self) -> usize {
        match self {
            Backend::Calendar(c) => c.len,
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            Backend::Heap(h) => h.len(),
        }
    }
}

/// A discrete-event queue over event payloads of type `Ev`.
#[derive(Debug)]
pub struct Engine<Ev> {
    now: SimTime,
    backend: Backend<Ev>,
    seq: u64,
    bucket_ns: u64,
    /// Seqs scheduled and neither fired nor cancelled: `pending()` is its
    /// exact size; disjoint from `cancelled` by construction.
    live: HashSet<u64>,
    /// Cancelled seqs physically still queued (reaped when their slot is
    /// popped) — bounded by the queue, never by history.
    cancelled: HashSet<u64>,
    dispatched: u64,
    peak_pending: usize,
    /// Debug cross-check: a key-only mirror of the calendar backend. Every
    /// physical pop must match its order exactly (release builds are
    /// pinned end-to-end by the randomized equivalence tests instead).
    #[cfg(debug_assertions)]
    shadow: BinaryHeap<Reverse<(SimTime, u64)>>,
}

impl<Ev> Default for Engine<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Ev> Engine<Ev> {
    pub fn new() -> Self {
        Self::with_bucket_ns(DEFAULT_BUCKET_NS)
    }

    /// Engine with a custom calendar bucket width (`engine.bucket_ns`;
    /// clamped to `[64, 1 MiB]` ns and rounded up to a power of two).
    pub fn with_bucket_ns(bucket_ns: u64) -> Self {
        let cal = Calendar::new(bucket_ns);
        let bucket_ns = cal.bucket_ns;
        Engine {
            now: SimTime::ZERO,
            backend: Backend::Calendar(cal),
            seq: 0,
            bucket_ns,
            live: HashSet::new(),
            cancelled: HashSet::new(),
            dispatched: 0,
            peak_pending: 0,
            #[cfg(debug_assertions)]
            shadow: BinaryHeap::new(),
        }
    }

    /// §Perf L6 reference mode: swap the calendar queue for the pre-L6
    /// binary heap. Pop order is identical by contract — the randomized
    /// equivalence tests (CI: `--features ref-alloc`) enforce it. Must be
    /// called before anything is scheduled.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_reference_mode(&mut self, on: bool) {
        assert!(
            self.backend.queued() == 0 && self.live.is_empty() && self.cancelled.is_empty(),
            "set_reference_mode on a non-empty engine"
        );
        self.backend = if on {
            Backend::Heap(BinaryHeap::new())
        } else {
            Backend::Calendar(Calendar::new(self.bucket_ns))
        };
    }

    /// True when running on the reference heap backend.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn reference_mode(&self) -> bool {
        matches!(self.backend, Backend::Heap(_))
    }

    #[cfg(debug_assertions)]
    #[inline]
    fn shadow_on(&self) -> bool {
        matches!(self.backend, Backend::Calendar(_))
    }

    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events dispatched so far (for the §Perf events/s metric).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Number of live events still pending. Exact: cancellations — before
    /// or after fire — never skew it (the pre-L6 `heap.len() -
    /// cancelled.len()` undercounted once a fired id was cancelled).
    pub fn pending(&self) -> usize {
        self.live.len()
    }

    /// Cancelled entries physically still queued. Bounded by `queued()`;
    /// the soak memory-flat regression test pins that cancel-after-fire
    /// contributes nothing.
    pub fn cancelled_backlog(&self) -> usize {
        self.cancelled.len()
    }

    /// Physically queued entries (live + cancelled tombstones).
    pub fn queued(&self) -> usize {
        self.backend.queued()
    }

    /// Scheduler work counters (§Perf L6).
    pub fn stats(&self) -> EngineStats {
        let (window_sorts, overflow_pulls, window_jumps) = match &self.backend {
            Backend::Calendar(c) => (c.window_sorts, c.overflow_pulls, c.window_jumps),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            Backend::Heap(_) => (0, 0, 0),
        };
        EngineStats {
            dispatched: self.dispatched,
            pending: self.live.len(),
            peak_pending: self.peak_pending,
            cancelled_backlog: self.cancelled.len(),
            window_sorts,
            overflow_pulls,
            window_jumps,
        }
    }

    /// Schedule `ev` to fire `delay` after now.
    pub fn schedule(&mut self, delay: SimTime, ev: Ev) -> EventId {
        self.schedule_at(self.now + delay, ev)
    }

    /// Schedule `ev` at an absolute time. Scheduling into the past is a
    /// hard error in every build: the release-mode clamp this replaced
    /// silently rewrote causality at scale (§Perf L6 satellite fix).
    pub fn schedule_at(&mut self, at: SimTime, ev: Ev) -> EventId {
        assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.live.insert(seq);
        self.peak_pending = self.peak_pending.max(self.live.len());
        #[cfg(debug_assertions)]
        if self.shadow_on() {
            self.shadow.push(Reverse((at, seq)));
        }
        self.backend.insert(Scheduled { at, seq, ev });
        EventId(seq)
    }

    /// Cancel a previously scheduled event. Idempotent; cancelling an
    /// already-fired event is an exact no-op (no tombstone, no count skew).
    pub fn cancel(&mut self, id: EventId) {
        if self.live.remove(&id.0) {
            self.cancelled.insert(id.0);
        }
    }

    /// Pop one physical entry, keeping the debug shadow in lock-step.
    fn pop_raw(&mut self) -> Option<Scheduled<Ev>> {
        let s = self.backend.pop_min()?;
        #[cfg(debug_assertions)]
        if self.shadow_on() {
            let Reverse(key) = self.shadow.pop().expect("shadow mirrors the calendar");
            assert_eq!(key, (s.at, s.seq), "calendar pop diverged from the reference order");
        }
        Some(s)
    }

    /// Pop the next live event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, Ev)> {
        while let Some(s) = self.pop_raw() {
            if self.cancelled.remove(&s.seq) {
                continue;
            }
            let was_live = self.live.remove(&s.seq);
            debug_assert!(was_live, "queued entry neither live nor cancelled");
            debug_assert!(s.at >= self.now);
            self.now = s.at;
            self.dispatched += 1;
            return Some((s.at, s.ev));
        }
        None
    }

    /// Peek at the timestamp of the next live event without firing it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        // Drop cancelled heads eagerly so peek is accurate.
        while let Some((at, seq)) = self.backend.peek_min() {
            if self.cancelled.contains(&seq) {
                let _ = self.pop_raw();
                self.cancelled.remove(&seq);
            } else {
                return Some(at);
            }
        }
        None
    }

    /// True if no live events remain.
    pub fn is_idle(&mut self) -> bool {
        self.live.is_empty()
    }

    /// Advance the clock over event-free time (§Soak time compression: a
    /// burst-idle-burst soak jumps the clock to the next burst boundary
    /// instead of simulating hours of silence). Must not skip over a
    /// pending event — the clock would then run backwards on its pop.
    pub fn advance_to(&mut self, t: SimTime) {
        if let Some(next) = self.peek_time() {
            assert!(t <= next, "advance_to({t}) would skip a pending event at {next}");
        }
        self.now = self.now.max(t);
    }
}

/// A faithful snapshot of an [`Engine`] (§Soak checkpointing): clock,
/// scheduling counter, dispatch counter, outstanding cancellations and the
/// pending queue *with original sequence numbers* — sequence numbers break
/// same-instant ties, so restoring them verbatim is what keeps a resumed
/// simulation's dispatch order identical to an uninterrupted run's.
/// Mode-agnostic: a state captured under either backend restores into
/// either backend with an identical future (the equivalence tests cut
/// checkpoints across modes to pin this).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineState<Ev> {
    pub now: SimTime,
    pub seq: u64,
    pub dispatched: u64,
    /// Outstanding cancelled seqs, ascending. Every entry refers to a
    /// still-queued event (the live/cancelled partition guarantees it).
    pub cancelled: Vec<u64>,
    /// Pending events as `(at, seq, ev)`, ascending by `(at, seq)`.
    pub pending: Vec<(SimTime, u64, Ev)>,
}

impl<Ev: Clone> Engine<Ev> {
    /// Capture the engine's complete state. The pending queue is emitted in
    /// deterministic `(at, seq)` order (the backends' internal layouts are
    /// not).
    pub fn checkpoint_state(&self) -> EngineState<Ev> {
        let mut cancelled: Vec<u64> = self.cancelled.iter().copied().collect();
        cancelled.sort_unstable();
        let mut pending: Vec<(SimTime, u64, Ev)> = Vec::with_capacity(self.backend.queued());
        match &self.backend {
            Backend::Calendar(c) => {
                pending.extend(c.active.iter().map(|s| (s.at, s.seq, s.ev.clone())));
                for b in &c.buckets {
                    pending.extend(b.iter().map(|s| (s.at, s.seq, s.ev.clone())));
                }
                pending.extend(c.overflow.iter().map(|Reverse(s)| (s.at, s.seq, s.ev.clone())));
            }
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            Backend::Heap(h) => {
                pending.extend(h.iter().map(|Reverse(s)| (s.at, s.seq, s.ev.clone())));
            }
        }
        pending.sort_unstable_by_key(|&(at, seq, _)| (at, seq));
        EngineState { now: self.now, seq: self.seq, dispatched: self.dispatched, cancelled, pending }
    }

    /// Rebuild an engine from a snapshot (calendar backend at the default
    /// bucket width; [`Engine::from_state_with`] picks the width). Stale
    /// cancellations — seqs matching no pending entry, as a pre-fix
    /// checkpoint could carry — are dropped rather than leaked.
    pub fn from_state(st: EngineState<Ev>) -> Self {
        Self::from_state_with(st, DEFAULT_BUCKET_NS)
    }

    /// [`Engine::from_state`] with an explicit calendar bucket width.
    pub fn from_state_with(st: EngineState<Ev>, bucket_ns: u64) -> Self {
        let mut e: Engine<Ev> = Engine::with_bucket_ns(bucket_ns);
        e.restore(st);
        e
    }

    /// Load a snapshot into this (empty) engine, keeping its backend mode —
    /// this is how the equivalence tests restore a calendar-mode snapshot
    /// into a reference-mode engine and vice versa.
    pub fn restore(&mut self, st: EngineState<Ev>) {
        assert!(
            self.backend.queued() == 0 && self.live.is_empty() && self.cancelled.is_empty(),
            "restore into a non-empty engine"
        );
        let queued: HashSet<u64> = st.pending.iter().map(|&(_, seq, _)| seq).collect();
        self.cancelled = st.cancelled.into_iter().filter(|s| queued.contains(s)).collect();
        self.live = queued.difference(&self.cancelled).copied().collect();
        for (at, seq, ev) in st.pending {
            assert!(seq < st.seq, "pending event seq {seq} beyond the scheduling counter");
            #[cfg(debug_assertions)]
            if self.shadow_on() {
                self.shadow.push(Reverse((at, seq)));
            }
            self.backend.insert(Scheduled { at, seq, ev });
        }
        self.now = st.now;
        self.seq = st.seq;
        self.dispatched = st.dispatched;
        self.peak_pending = self.live.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fires_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(30), 3);
        e.schedule(SimTime::ns(10), 1);
        e.schedule(SimTime::ns(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(e.now().as_ns(), 30);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..100 {
            e.schedule(SimTime::ns(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn cancellation_skips_event() {
        let mut e: Engine<&str> = Engine::new();
        let a = e.schedule(SimTime::ns(10), "a");
        e.schedule(SimTime::ns(20), "b");
        e.cancel(a);
        assert_eq!(e.pop().map(|(_, v)| v), Some("b"));
        assert!(e.pop().is_none());
    }

    #[test]
    fn cancel_is_idempotent_and_safe_after_fire() {
        let mut e: Engine<u8> = Engine::new();
        let a = e.schedule(SimTime::ns(1), 1);
        assert_eq!(e.pending(), 1);
        e.cancel(a);
        assert_eq!(e.pending(), 0);
        e.cancel(a); // double cancel: exact no-op
        assert_eq!(e.pending(), 0);
        assert!(e.pop().is_none());
        let b = e.schedule(SimTime::ns(2), 2);
        assert_eq!(e.pending(), 1);
        assert_eq!(e.pop().map(|(_, v)| v), Some(2));
        assert_eq!(e.pending(), 0);
        e.cancel(b); // already fired — must not poison future pops...
        let c = e.schedule(SimTime::ns(3), 3);
        // ...and must not skew the live count (the pre-L6 accounting
        // subtracted the stale tombstone from `heap.len()` and reported 0
        // here) or leave a tombstone behind.
        assert_eq!(e.pending(), 1);
        assert_eq!(e.cancelled_backlog(), 0);
        assert_eq!(e.pop().map(|(_, v)| v), Some(3));
        e.cancel(c);
        e.cancel(b);
        assert_eq!(e.pending(), 0);
        assert_eq!(e.cancelled_backlog(), 0);
    }

    #[test]
    fn tombstones_are_bounded_by_queued_entries() {
        let mut e: Engine<u64> = Engine::new();
        // Soak-shaped churn: schedule, fire, then cancel the fired id —
        // repeated millions of times this must stay memory-flat.
        for i in 0..10_000u64 {
            let id = e.schedule(SimTime::ns(1), i);
            let _ = e.pop();
            e.cancel(id);
            assert_eq!(e.cancelled_backlog(), 0);
            assert_eq!(e.queued(), 0);
        }
        // Cancel-before-fire tombstones exist only while physically queued.
        let ids: Vec<EventId> = (0..100).map(|i| e.schedule(SimTime::ns(5), i)).collect();
        for &id in &ids {
            e.cancel(id);
        }
        assert_eq!(e.cancelled_backlog(), 100);
        assert_eq!(e.pending(), 0);
        assert!(e.pop().is_none());
        assert_eq!(e.cancelled_backlog(), 0, "popping the slots reaps the tombstones");
        assert_eq!(e.queued(), 0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn schedule_into_the_past_is_a_hard_error() {
        let mut e: Engine<u8> = Engine::new();
        e.schedule_at(SimTime::ns(100), 1);
        let _ = e.pop();
        // Pre-L6 release builds silently clamped this to `now`.
        e.schedule_at(SimTime::ns(99), 2);
    }

    #[test]
    fn clock_monotonic_and_events_counted() {
        let mut e: Engine<u64> = Engine::new();
        let mut last = SimTime::ZERO;
        for i in 0..1000u64 {
            e.schedule(SimTime::ns(i % 17), i);
        }
        let mut n = 0;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 1000);
        assert_eq!(e.dispatched(), 1000);
        assert_eq!(e.stats().peak_pending, 1000);
    }

    #[test]
    fn peek_respects_cancellation() {
        let mut e: Engine<u8> = Engine::new();
        let a = e.schedule(SimTime::ns(5), 1);
        e.schedule(SimTime::ns(9), 2);
        e.cancel(a);
        assert_eq!(e.peek_time(), Some(SimTime::ns(9)));
        assert!(!e.is_idle());
    }

    #[test]
    fn advance_to_compresses_idle_time_only() {
        let mut e: Engine<u8> = Engine::new();
        e.advance_to(SimTime::ns(500));
        assert_eq!(e.now().as_ns(), 500);
        // Backwards advance is a no-op, not a clock reset.
        e.advance_to(SimTime::ns(100));
        assert_eq!(e.now().as_ns(), 500);
        e.schedule_at(SimTime::ns(900), 1);
        e.advance_to(SimTime::ns(900)); // exactly at the pending event: allowed
        assert_eq!(e.pop().map(|(t, v)| (t.as_ns(), v)), Some((900, 1)));
    }

    #[test]
    #[should_panic(expected = "skip a pending event")]
    fn advance_to_refuses_to_skip_events() {
        let mut e: Engine<u8> = Engine::new();
        e.schedule_at(SimTime::ns(10), 1);
        e.advance_to(SimTime::ns(11));
    }

    #[test]
    fn overflow_and_idle_jumps_preserve_order() {
        // Events beyond one calendar day (4 µs × 1024 ≈ 4.2 ms) land in
        // the overflow heap; pops must still come out in (at, seq) order
        // across day boundaries and hours-long idle jumps.
        let mut e: Engine<u64> = Engine::new();
        let day = (NBUCKETS as u64) * DEFAULT_BUCKET_NS;
        let times = [
            0,
            1,
            day - 1,
            day,
            day + 1,
            3 * day,
            3 * day,
            10 * day + 7,
            3_600_000_000_000, // one hour out
            3_600_000_000_001,
        ];
        for (i, &t) in times.iter().enumerate() {
            e.schedule_at(SimTime::ns(t), i as u64);
        }
        let fired: Vec<(u64, u64)> =
            std::iter::from_fn(|| e.pop().map(|(t, v)| (t.as_ns(), v))).collect();
        let mut want: Vec<(u64, u64)> =
            times.iter().enumerate().map(|(i, &t)| (t, i as u64)).collect();
        want.sort_unstable();
        assert_eq!(fired, want);
        assert!(e.stats().window_jumps >= 1, "hour-out event must be reached by a jump");
        assert!(e.stats().overflow_pulls >= 1);
    }

    #[test]
    fn snapshot_restore_preserves_order_counters_and_cancellations() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10 {
            e.schedule(SimTime::ns(5 + (i % 3)), i);
        }
        let dead = e.schedule(SimTime::ns(6), 99);
        e.cancel(dead);
        e.pop();
        e.pop();

        let st = e.checkpoint_state();
        let mut resumed = Engine::from_state(st.clone());
        assert_eq!(resumed.now(), e.now());
        assert_eq!(resumed.dispatched(), e.dispatched());
        assert_eq!(resumed.pending(), e.pending());
        assert_eq!(resumed.cancelled_backlog(), e.cancelled_backlog());

        // Both engines must drain identically, including new events scheduled
        // after the snapshot (same seq counter ⇒ same FIFO tie-breaks).
        e.schedule(SimTime::ns(1), 1000);
        resumed.schedule(SimTime::ns(1), 1000);
        let a: Vec<(u64, u32)> =
            std::iter::from_fn(|| e.pop().map(|(t, v)| (t.as_ns(), v))).collect();
        let b: Vec<(u64, u32)> =
            std::iter::from_fn(|| resumed.pop().map(|(t, v)| (t.as_ns(), v))).collect();
        assert_eq!(a, b);
        assert!(!a.iter().any(|&(_, v)| v == 99), "cancelled event fired after restore");
        assert_eq!(e.dispatched(), resumed.dispatched());

        // The snapshot itself is deterministic: sorted pending, sorted cancels.
        assert!(st.pending.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
        assert!(st.cancelled.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn restore_drops_stale_cancellations() {
        // A pre-fix checkpoint could carry tombstones for already-fired
        // seqs; restoring must not leak them into the accounting.
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(4), 7);
        let mut st = e.checkpoint_state();
        st.cancelled = vec![999_999]; // matches nothing pending
        let mut r = Engine::from_state(st);
        assert_eq!(r.pending(), 1);
        assert_eq!(r.cancelled_backlog(), 0);
        assert_eq!(r.pop().map(|(_, v)| v), Some(7));
    }

    #[test]
    fn schedule_during_run() {
        // An event handler scheduling follow-ups is the normal pattern.
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(1), 0);
        let mut fired = vec![];
        while let Some((_, v)) = e.pop() {
            fired.push(v);
            if v < 5 {
                e.schedule(SimTime::ns(1), v + 1);
            }
        }
        assert_eq!(fired, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(e.now().as_ns(), 6);
    }

    /// One random engine op applied identically to every engine in
    /// `engines`. Delays mix near (in-bucket), same-instant (FIFO ties),
    /// day-boundary and far-overflow horizons so every calendar path —
    /// active insert, bucket insert, overflow, pulls, jumps — is hit.
    fn random_op(
        rng: &mut Rng,
        engines: &mut [&mut Engine<u64>],
        ids: &mut Vec<EventId>,
        next_val: &mut u64,
    ) -> Vec<Option<(u64, u64)>> {
        let day = (NBUCKETS as u64) * DEFAULT_BUCKET_NS;
        match rng.below(10) {
            0..=3 => {
                let delay = match rng.below(5) {
                    0 => 0,
                    1 => rng.below(64),
                    2 => rng.below(DEFAULT_BUCKET_NS * 4),
                    3 => day - 2 + rng.below(4),
                    _ => day * (1 + rng.below(19)) + rng.below(1000),
                };
                let v = *next_val;
                *next_val += 1;
                let mut id = None;
                for e in engines.iter_mut() {
                    id = Some(e.schedule(SimTime::ns(delay), v));
                }
                ids.push(id.expect("at least one engine"));
                Vec::new()
            }
            4..=5 if !ids.is_empty() => {
                // Cancel a random previously issued id — fired or not.
                let id = ids[rng.below(ids.len() as u64) as usize];
                for e in engines.iter_mut() {
                    e.cancel(id);
                }
                Vec::new()
            }
            6 => {
                // Advance over idle time, capped at the next pending event.
                let step = rng.below(day * 3);
                for e in engines.iter_mut() {
                    let cap = e.peek_time().map_or(u64::MAX, |t| t.as_ns());
                    let t = (e.now().as_ns() + step).min(cap);
                    e.advance_to(SimTime::ns(t));
                }
                Vec::new()
            }
            _ => engines
                .iter_mut()
                .map(|e| e.pop().map(|(t, v)| (t.as_ns(), v)))
                .collect(),
        }
    }

    #[test]
    fn randomized_equivalence_calendar_vs_reference_heap() {
        // §Perf L6 acceptance: the calendar backend's observable behaviour
        // — pop sequence, peeks, pending counts, snapshots — is
        // bit-identical to the reference heap's on randomized
        // trajectories, including across checkpoint/resume cuts that
        // restore each mode's snapshot into the OTHER mode.
        for seed in 0..8u64 {
            let mut rng = Rng::new(0x6E61 + seed);
            let mut cal: Engine<u64> = Engine::new();
            let mut heap: Engine<u64> = Engine::new();
            heap.set_reference_mode(true);
            assert!(heap.reference_mode() && !cal.reference_mode());
            let mut ids = Vec::new();
            let mut next_val = 0u64;
            for step in 0..2_000 {
                {
                    let mut both = [&mut cal, &mut heap];
                    let outs = random_op(&mut rng, &mut both, &mut ids, &mut next_val);
                    if outs.len() == 2 {
                        assert_eq!(outs[0], outs[1], "pop diverged at step {step}");
                    }
                }
                assert_eq!(cal.peek_time(), heap.peek_time());
                assert_eq!(cal.pending(), heap.pending());
                assert_eq!(cal.now(), heap.now());
                if step % 403 == 0 {
                    // Checkpoint cut: snapshots are mode-agnostic and equal.
                    let sc = cal.checkpoint_state();
                    let sh = heap.checkpoint_state();
                    assert_eq!(sc, sh, "snapshots diverged at step {step}");
                    // Cross-restore: heap state → calendar engine and back.
                    cal = Engine::from_state(sh);
                    let mut h: Engine<u64> = Engine::new();
                    h.set_reference_mode(true);
                    h.restore(sc);
                    heap = h;
                }
            }
            // Drain to the end in lock-step.
            loop {
                let a = cal.pop().map(|(t, v)| (t.as_ns(), v));
                let b = heap.pop().map(|(t, v)| (t.as_ns(), v));
                assert_eq!(a, b);
                if a.is_none() {
                    break;
                }
            }
            assert_eq!(cal.dispatched(), heap.dispatched());
            assert_eq!(cal.cancelled_backlog(), 0);
            assert_eq!(heap.cancelled_backlog(), 0);
        }
    }

    #[test]
    fn randomized_pending_matches_drain_and_snapshots_round_trip() {
        // Satellite: `pending()` must equal the actual remaining drain
        // count after ANY interleaving of schedule/schedule_at/cancel
        // (before and after fire)/pop/advance_to, and `checkpoint_state`
        // → `from_state` must round-trip bit-identically at every cut
        // point — in both scheduler modes.
        for reference in [false, true] {
            for seed in 0..4u64 {
                let mut rng = Rng::new(0xACC7 + seed * 31 + reference as u64);
                let mut e: Engine<u64> = Engine::new();
                if reference {
                    e.set_reference_mode(true);
                }
                let mut ids = Vec::new();
                let mut next_val = 0u64;
                for _ in 0..1_200 {
                    {
                        let mut one = [&mut e];
                        let _ = random_op(&mut rng, &mut one, &mut ids, &mut next_val);
                    }
                    // Round-trip at every cut point: the restored engine's
                    // snapshot is the identical snapshot.
                    let st = e.checkpoint_state();
                    let mut r: Engine<u64> = Engine::new();
                    if reference {
                        r.set_reference_mode(true);
                    }
                    r.restore(st.clone());
                    assert_eq!(r.checkpoint_state(), st);
                    // `pending()` equals the true remaining drain count.
                    let mut probe = Engine::from_state(st);
                    let mut drained = 0usize;
                    while probe.pop().is_some() {
                        drained += 1;
                    }
                    assert_eq!(e.pending(), drained, "pending() diverged from drain count");
                    // Tombstones plus live events account for every slot.
                    assert_eq!(e.queued(), e.pending() + e.cancelled_backlog());
                }
            }
        }
    }
}
