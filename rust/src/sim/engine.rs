//! The event engine: a time-ordered queue of typed events with cancellation.
//!
//! The engine is *not* an actor framework — event payloads are a plain enum
//! owned by the simulation (`ClusterSim` dispatches them in one big match).
//! That keeps the hot loop branch-predictable and allocation-free, which is
//! what lets cluster-scale experiments (thousands of ranks × thousands of
//! chunks) run in milliseconds. See `benches/simcore.rs` for the events/sec
//! target (§Perf: ≥1M events/s).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::SimTime;

/// Handle to a scheduled event, usable to cancel it before it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

#[derive(Debug)]
struct Scheduled<Ev> {
    at: SimTime,
    seq: u64,
    ev: Ev,
}

// Order by (time, seq): seq breaks ties FIFO so simultaneous events fire in
// scheduling order — crucial for determinism.
impl<Ev> PartialEq for Scheduled<Ev> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<Ev> Eq for Scheduled<Ev> {}
impl<Ev> PartialOrd for Scheduled<Ev> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<Ev> Ord for Scheduled<Ev> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// A discrete-event queue over event payloads of type `Ev`.
pub struct Engine<Ev> {
    now: SimTime,
    heap: BinaryHeap<Reverse<Scheduled<Ev>>>,
    seq: u64,
    // Cancelled event seqs. Kept sorted-free: membership is checked lazily on
    // pop. Size is bounded by the number of outstanding cancellations.
    cancelled: std::collections::HashSet<u64>,
    dispatched: u64,
}

impl<Ev> Default for Engine<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Ev> Engine<Ev> {
    pub fn new() -> Self {
        Engine {
            now: SimTime::ZERO,
            heap: BinaryHeap::new(),
            seq: 0,
            cancelled: std::collections::HashSet::new(),
            dispatched: 0,
        }
    }

    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events dispatched so far (for the §Perf events/s metric).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.heap.len() - self.cancelled.len().min(self.heap.len())
    }

    /// Schedule `ev` to fire `delay` after now.
    pub fn schedule(&mut self, delay: SimTime, ev: Ev) -> EventId {
        self.schedule_at(self.now + delay, ev)
    }

    /// Schedule `ev` at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, at: SimTime, ev: Ev) -> EventId {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { at, seq, ev }));
        EventId(seq)
    }

    /// Cancel a previously scheduled event. Idempotent; cancelling an
    /// already-fired event is a no-op.
    pub fn cancel(&mut self, id: EventId) {
        self.cancelled.insert(id.0);
    }

    /// Pop the next live event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, Ev)> {
        while let Some(Reverse(s)) = self.heap.pop() {
            if self.cancelled.remove(&s.seq) {
                continue;
            }
            debug_assert!(s.at >= self.now);
            self.now = s.at;
            self.dispatched += 1;
            return Some((s.at, s.ev));
        }
        None
    }

    /// Peek at the timestamp of the next live event without firing it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        // Drop cancelled heads eagerly so peek is accurate.
        while let Some(Reverse(s)) = self.heap.peek() {
            if self.cancelled.contains(&s.seq) {
                let seq = s.seq;
                self.heap.pop();
                self.cancelled.remove(&seq);
            } else {
                return Some(s.at);
            }
        }
        None
    }

    /// True if no live events remain.
    pub fn is_idle(&mut self) -> bool {
        self.peek_time().is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(30), 3);
        e.schedule(SimTime::ns(10), 1);
        e.schedule(SimTime::ns(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(e.now().as_ns(), 30);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..100 {
            e.schedule(SimTime::ns(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn cancellation_skips_event() {
        let mut e: Engine<&str> = Engine::new();
        let a = e.schedule(SimTime::ns(10), "a");
        e.schedule(SimTime::ns(20), "b");
        e.cancel(a);
        assert_eq!(e.pop().map(|(_, v)| v), Some("b"));
        assert!(e.pop().is_none());
    }

    #[test]
    fn cancel_is_idempotent_and_safe_after_fire() {
        let mut e: Engine<u8> = Engine::new();
        let a = e.schedule(SimTime::ns(1), 1);
        e.cancel(a);
        e.cancel(a);
        assert!(e.pop().is_none());
        let b = e.schedule(SimTime::ns(2), 2);
        assert_eq!(e.pop().map(|(_, v)| v), Some(2));
        e.cancel(b); // already fired — must not poison future pops
        e.schedule(SimTime::ns(3), 3);
        assert_eq!(e.pop().map(|(_, v)| v), Some(3));
    }

    #[test]
    fn clock_monotonic_and_events_counted() {
        let mut e: Engine<u64> = Engine::new();
        let mut last = SimTime::ZERO;
        for i in 0..1000u64 {
            e.schedule(SimTime::ns(i % 17), i);
        }
        let mut n = 0;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 1000);
        assert_eq!(e.dispatched(), 1000);
    }

    #[test]
    fn peek_respects_cancellation() {
        let mut e: Engine<u8> = Engine::new();
        let a = e.schedule(SimTime::ns(5), 1);
        e.schedule(SimTime::ns(9), 2);
        e.cancel(a);
        assert_eq!(e.peek_time(), Some(SimTime::ns(9)));
        assert!(!e.is_idle());
    }

    #[test]
    fn schedule_during_run() {
        // An event handler scheduling follow-ups is the normal pattern.
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(1), 0);
        let mut fired = vec![];
        while let Some((_, v)) = e.pop() {
            fired.push(v);
            if v < 5 {
                e.schedule(SimTime::ns(1), v + 1);
            }
        }
        assert_eq!(fired, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(e.now().as_ns(), 6);
    }
}
