//! The event engine: a time-ordered queue of typed events with cancellation.
//!
//! The engine is *not* an actor framework — event payloads are a plain enum
//! owned by the simulation (`ClusterSim` dispatches them in one big match).
//! That keeps the hot loop branch-predictable and allocation-free, which is
//! what lets cluster-scale experiments (thousands of ranks × thousands of
//! chunks) run in milliseconds. See `benches/simcore.rs` for the events/sec
//! target (§Perf: ≥1M events/s).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::SimTime;

/// Handle to a scheduled event, usable to cancel it before it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

#[derive(Debug)]
struct Scheduled<Ev> {
    at: SimTime,
    seq: u64,
    ev: Ev,
}

// Order by (time, seq): seq breaks ties FIFO so simultaneous events fire in
// scheduling order — crucial for determinism.
impl<Ev> PartialEq for Scheduled<Ev> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<Ev> Eq for Scheduled<Ev> {}
impl<Ev> PartialOrd for Scheduled<Ev> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<Ev> Ord for Scheduled<Ev> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// A discrete-event queue over event payloads of type `Ev`.
pub struct Engine<Ev> {
    now: SimTime,
    heap: BinaryHeap<Reverse<Scheduled<Ev>>>,
    seq: u64,
    // Cancelled event seqs. Kept sorted-free: membership is checked lazily on
    // pop. Size is bounded by the number of outstanding cancellations.
    cancelled: std::collections::HashSet<u64>,
    dispatched: u64,
}

impl<Ev> Default for Engine<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Ev> Engine<Ev> {
    pub fn new() -> Self {
        Engine {
            now: SimTime::ZERO,
            heap: BinaryHeap::new(),
            seq: 0,
            cancelled: std::collections::HashSet::new(),
            dispatched: 0,
        }
    }

    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events dispatched so far (for the §Perf events/s metric).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.heap.len() - self.cancelled.len().min(self.heap.len())
    }

    /// Schedule `ev` to fire `delay` after now.
    pub fn schedule(&mut self, delay: SimTime, ev: Ev) -> EventId {
        self.schedule_at(self.now + delay, ev)
    }

    /// Schedule `ev` at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, at: SimTime, ev: Ev) -> EventId {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { at, seq, ev }));
        EventId(seq)
    }

    /// Cancel a previously scheduled event. Idempotent; cancelling an
    /// already-fired event is a no-op.
    pub fn cancel(&mut self, id: EventId) {
        self.cancelled.insert(id.0);
    }

    /// Pop the next live event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, Ev)> {
        while let Some(Reverse(s)) = self.heap.pop() {
            if self.cancelled.remove(&s.seq) {
                continue;
            }
            debug_assert!(s.at >= self.now);
            self.now = s.at;
            self.dispatched += 1;
            return Some((s.at, s.ev));
        }
        None
    }

    /// Peek at the timestamp of the next live event without firing it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        // Drop cancelled heads eagerly so peek is accurate.
        while let Some(Reverse(s)) = self.heap.peek() {
            if self.cancelled.contains(&s.seq) {
                let seq = s.seq;
                self.heap.pop();
                self.cancelled.remove(&seq);
            } else {
                return Some(s.at);
            }
        }
        None
    }

    /// True if no live events remain.
    pub fn is_idle(&mut self) -> bool {
        self.peek_time().is_none()
    }

    /// Advance the clock over event-free time (§Soak time compression: a
    /// burst-idle-burst soak jumps the clock to the next burst boundary
    /// instead of simulating hours of silence). Must not skip over a
    /// pending event — the clock would then run backwards on its pop.
    pub fn advance_to(&mut self, t: SimTime) {
        if let Some(next) = self.peek_time() {
            assert!(t <= next, "advance_to({t}) would skip a pending event at {next}");
        }
        self.now = self.now.max(t);
    }
}

/// A faithful snapshot of an [`Engine`] (§Soak checkpointing): clock,
/// scheduling counter, dispatch counter, outstanding cancellations and the
/// pending queue *with original sequence numbers* — sequence numbers break
/// same-instant ties, so restoring them verbatim is what keeps a resumed
/// simulation's dispatch order identical to an uninterrupted run's.
#[derive(Debug, Clone)]
pub struct EngineState<Ev> {
    pub now: SimTime,
    pub seq: u64,
    pub dispatched: u64,
    /// Outstanding cancelled seqs, ascending.
    pub cancelled: Vec<u64>,
    /// Pending events as `(at, seq, ev)`, ascending by `(at, seq)`.
    pub pending: Vec<(SimTime, u64, Ev)>,
}

impl<Ev: Clone> Engine<Ev> {
    /// Capture the engine's complete state. The pending queue is emitted in
    /// deterministic `(at, seq)` order (the heap's internal layout is not).
    pub fn checkpoint_state(&self) -> EngineState<Ev> {
        let mut cancelled: Vec<u64> = self.cancelled.iter().copied().collect();
        cancelled.sort_unstable();
        let mut pending: Vec<(SimTime, u64, Ev)> = self
            .heap
            .iter()
            .map(|Reverse(s)| (s.at, s.seq, s.ev.clone()))
            .collect();
        pending.sort_unstable_by_key(|&(at, seq, _)| (at, seq));
        EngineState { now: self.now, seq: self.seq, dispatched: self.dispatched, cancelled, pending }
    }

    /// Rebuild an engine from a snapshot.
    pub fn from_state(st: EngineState<Ev>) -> Self {
        let mut heap = BinaryHeap::with_capacity(st.pending.len());
        for (at, seq, ev) in st.pending {
            assert!(seq < st.seq, "pending event seq {seq} beyond the scheduling counter");
            heap.push(Reverse(Scheduled { at, seq, ev }));
        }
        Engine {
            now: st.now,
            heap,
            seq: st.seq,
            cancelled: st.cancelled.into_iter().collect(),
            dispatched: st.dispatched,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(30), 3);
        e.schedule(SimTime::ns(10), 1);
        e.schedule(SimTime::ns(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(e.now().as_ns(), 30);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..100 {
            e.schedule(SimTime::ns(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn cancellation_skips_event() {
        let mut e: Engine<&str> = Engine::new();
        let a = e.schedule(SimTime::ns(10), "a");
        e.schedule(SimTime::ns(20), "b");
        e.cancel(a);
        assert_eq!(e.pop().map(|(_, v)| v), Some("b"));
        assert!(e.pop().is_none());
    }

    #[test]
    fn cancel_is_idempotent_and_safe_after_fire() {
        let mut e: Engine<u8> = Engine::new();
        let a = e.schedule(SimTime::ns(1), 1);
        e.cancel(a);
        e.cancel(a);
        assert!(e.pop().is_none());
        let b = e.schedule(SimTime::ns(2), 2);
        assert_eq!(e.pop().map(|(_, v)| v), Some(2));
        e.cancel(b); // already fired — must not poison future pops
        e.schedule(SimTime::ns(3), 3);
        assert_eq!(e.pop().map(|(_, v)| v), Some(3));
    }

    #[test]
    fn clock_monotonic_and_events_counted() {
        let mut e: Engine<u64> = Engine::new();
        let mut last = SimTime::ZERO;
        for i in 0..1000u64 {
            e.schedule(SimTime::ns(i % 17), i);
        }
        let mut n = 0;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 1000);
        assert_eq!(e.dispatched(), 1000);
    }

    #[test]
    fn peek_respects_cancellation() {
        let mut e: Engine<u8> = Engine::new();
        let a = e.schedule(SimTime::ns(5), 1);
        e.schedule(SimTime::ns(9), 2);
        e.cancel(a);
        assert_eq!(e.peek_time(), Some(SimTime::ns(9)));
        assert!(!e.is_idle());
    }

    #[test]
    fn advance_to_compresses_idle_time_only() {
        let mut e: Engine<u8> = Engine::new();
        e.advance_to(SimTime::ns(500));
        assert_eq!(e.now().as_ns(), 500);
        // Backwards advance is a no-op, not a clock reset.
        e.advance_to(SimTime::ns(100));
        assert_eq!(e.now().as_ns(), 500);
        e.schedule_at(SimTime::ns(900), 1);
        e.advance_to(SimTime::ns(900)); // exactly at the pending event: allowed
        assert_eq!(e.pop().map(|(t, v)| (t.as_ns(), v)), Some((900, 1)));
    }

    #[test]
    #[should_panic(expected = "skip a pending event")]
    fn advance_to_refuses_to_skip_events() {
        let mut e: Engine<u8> = Engine::new();
        e.schedule_at(SimTime::ns(10), 1);
        e.advance_to(SimTime::ns(11));
    }

    #[test]
    fn snapshot_restore_preserves_order_counters_and_cancellations() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10 {
            e.schedule(SimTime::ns(5 + (i % 3)), i);
        }
        let dead = e.schedule(SimTime::ns(6), 99);
        e.cancel(dead);
        e.pop();
        e.pop();

        let st = e.checkpoint_state();
        let mut resumed = Engine::from_state(st.clone());
        assert_eq!(resumed.now(), e.now());
        assert_eq!(resumed.dispatched(), e.dispatched());
        assert_eq!(resumed.pending(), e.pending());

        // Both engines must drain identically, including new events scheduled
        // after the snapshot (same seq counter ⇒ same FIFO tie-breaks).
        e.schedule(SimTime::ns(1), 1000);
        resumed.schedule(SimTime::ns(1), 1000);
        let a: Vec<(u64, u32)> =
            std::iter::from_fn(|| e.pop().map(|(t, v)| (t.as_ns(), v))).collect();
        let b: Vec<(u64, u32)> =
            std::iter::from_fn(|| resumed.pop().map(|(t, v)| (t.as_ns(), v))).collect();
        assert_eq!(a, b);
        assert!(!a.iter().any(|&(_, v)| v == 99), "cancelled event fired after restore");
        assert_eq!(e.dispatched(), resumed.dispatched());

        // The snapshot itself is deterministic: sorted pending, sorted cancels.
        assert!(st.pending.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
        assert!(st.cancelled.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn schedule_during_run() {
        // An event handler scheduling follow-ups is the normal pattern.
        let mut e: Engine<u32> = Engine::new();
        e.schedule(SimTime::ns(1), 0);
        let mut fired = vec![];
        while let Some((_, v)) = e.pop() {
            fired.push(v);
            if v < 5 {
                e.schedule(SimTime::ns(1), v + 1);
            }
        }
        assert_eq!(fired, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(e.now().as_ns(), 6);
    }
}
