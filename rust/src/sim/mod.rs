//! Discrete-event simulation core.
//!
//! Everything in the simulated cluster — NIC transmissions, kernel
//! completions, proxy polling, failure injection — is an event on a single
//! nanosecond-resolution virtual clock. The engine keeps `(time, seq,
//! event)` entries with stable FIFO ordering for simultaneous events and
//! O(1) amortized cancellation (needed when fluid flows are re-rated and
//! their completion events must be invalidated). Since §Perf L6 the
//! default backend is a calendar queue (bucketed windows + overflow heap)
//! sized for multi-thousand-node presets; the original binary heap
//! survives as the cross-checked reference mode.

mod engine;
mod time;

pub use engine::{Engine, EngineState, EngineStats, EventId, DEFAULT_BUCKET_NS};
pub use time::SimTime;
