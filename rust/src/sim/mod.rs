//! Discrete-event simulation core.
//!
//! Everything in the simulated cluster — NIC transmissions, kernel
//! completions, proxy polling, failure injection — is an event on a single
//! nanosecond-resolution virtual clock. The engine is deliberately minimal:
//! a binary heap of `(time, seq, event)` with stable FIFO ordering for
//! simultaneous events and O(1) amortized cancellation (needed when fluid
//! flows are re-rated and their completion events must be invalidated).

mod engine;
mod time;

pub use engine::{Engine, EngineState, EventId};
pub use time::SimTime;
