//! Offline, dependency-free shim of the [`anyhow`](https://docs.rs/anyhow)
//! API surface the `vccl` crate uses: [`Error`], [`Result`], the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the [`Context`]
//! extension trait.
//!
//! The build image has no crates.io access, so this crate stands in for the
//! real one as a path dependency. It is intentionally tiny: errors are a
//! message string plus an optional chain of `context` annotations — enough
//! for CLI diagnostics, not a general error-handling framework. Replacing it
//! with the real `anyhow = "1"` requires no source changes in `vccl`.

use std::fmt;

/// A string-backed error value, Display-formatted like `anyhow::Error`
/// (outermost context first, then the original message after `: `).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend a context annotation, matching anyhow's `context: cause`
    /// rendering.
    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `.unwrap()` on a Result<_, Error> prints this; keep it readable.
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` — that
// is what keeps this blanket conversion coherent (same trick as the real
// anyhow crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string: `anyhow!("bad {x:?}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("unknown transport {t:?}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds:
/// `ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len())`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Annotate the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Annotate the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> Result<u32> {
        let n: u32 = v.parse().map_err(|e| anyhow!("bad value {v:?}: {e}"))?;
        ensure!(n > 0, "value must be positive, got {n}");
        Ok(n)
    }

    #[test]
    fn macro_formats_and_propagates() {
        assert_eq!(parse("3").unwrap(), 3);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().contains("bad value \"x\""));
        let e = parse("0").unwrap_err();
        assert!(e.to_string().contains("positive"));
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("writing {}", "report")).unwrap_err();
        let text = e.to_string();
        assert!(text.starts_with("writing report: "), "{text}");
        let none: Option<u8> = None;
        assert_eq!(none.context("missing key").unwrap_err().to_string(), "missing key");
    }
}
