//! Cross-module integration tests: the full stack (topology → net → gpu →
//! ccl → fault → monitor → pipeline) driven through the public API, plus
//! RNG-driven property sweeps (proptest is unavailable in the offline
//! vendored build; these use seeded exhaustive/random case generation).

use vccl::ccl::{ClusterSim, CollKind};
use vccl::config::{Config, Transport};
use vccl::coordinator::{self, bench, Command, EXPERIMENTS};
use vccl::monitor::Verdict;
use vccl::pipeline::{PipelineCfg, PipelineSim};
use vccl::sim::SimTime;
use vccl::topology::RankId;
use vccl::util::{ByteSize, Rng};

/// Debug builds run the same properties with fewer random cases (the
/// un-optimized simulator is ~10× slower; coverage is a release concern).
const CASES: usize = if cfg!(debug_assertions) { 5 } else { 30 };
const FT_CASES: usize = if cfg!(debug_assertions) { 4 } else { 20 };

fn fast_cfg() -> Config {
    let mut c = Config::paper_defaults();
    c.net.ib_timeout_exp = 10;
    c.net.ib_retry_cnt = 2;
    c.net.qp_warmup_ns = 50_000_000;
    c.vccl.channels = 2;
    c
}

// ---------------------------------------------------------------------
// Conservation / correctness invariants
// ---------------------------------------------------------------------

/// Property: every submitted byte is delivered exactly once, for random
/// sizes, random (src,dst) pairs and every transport.
#[test]
fn property_p2p_conserves_bytes() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        let transport = *rng.choose(&["kernel", "ncclx", "smfree"]);
        let mut cfg = fast_cfg();
        cfg.set_key("vccl.transport", transport).unwrap();
        let mut s = ClusterSim::new(cfg);
        let n = s.topo.num_ranks();
        let src = RankId(rng.below(n as u64) as usize);
        let mut dst = RankId(rng.below(n as u64) as usize);
        if dst == src {
            dst = RankId((src.0 + 1) % n);
        }
        let bytes = rng.range(1, 8 << 20);
        let id = s.submit_p2p(src, dst, bytes);
        // Mid-flight checkpoint: the live records must satisfy the
        // send-pointer ordering (posted ≥ transmitted ≥ acked) the old
        // retained-record sweep used to assert at quiescence.
        s.run_until(SimTime::us(30));
        for x in s.xfers.iter_live() {
            assert!(x.send.invariant_ok(), "case {case}: {:?}", x.send);
            assert!(x.recv.invariant_ok(), "case {case}: {:?}", x.recv);
        }
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done(), "case {case}: {src}->{dst} {bytes}B {transport}");
        // Chunk conservation via the §Perf L5 roll-up (the transfer
        // records themselves are recycled at completion): with no failure
        // injected, the chunks put on the wire must equal the chunks
        // delivered exactly — a phantom transmission (stale event driving
        // a recycled slot, double-pumped chunk) breaks this equality.
        let o = &s.ops[id.0];
        let wire: u64 = o.chan_rollup.iter().map(|c| c.chunks_wire).sum();
        let delivered: u64 = o.chan_rollup.iter().map(|c| c.chunks).sum();
        assert_eq!(wire, delivered, "case {case}: wire/delivered chunk mismatch");
        assert_eq!(
            o.chan_rollup.iter().map(|c| c.xfers).sum::<u64>(),
            o.channels as u64,
            "case {case}: one transfer per channel"
        );
        assert_eq!(s.xfers.live(), 0, "case {case}: all transfers recycled");
    }
}

/// Property: collectives complete for every kind × transport × size combo.
#[test]
fn property_collectives_always_complete() {
    let kinds = [CollKind::AllReduce, CollKind::AllGather, CollKind::ReduceScatter,
                 CollKind::AllToAll];
    let mut rng = Rng::new(0xC0FFEE);
    for &kind in &kinds {
        for transport in ["kernel", "smfree"] {
            let mut cfg = fast_cfg();
            cfg.set_key("vccl.transport", transport).unwrap();
            let mut s = ClusterSim::new(cfg);
            let bytes = rng.range(1 << 16, 16 << 20);
            let id = s.submit(kind, bytes);
            s.run_to_idle(100_000_000);
            assert!(s.ops[id.0].is_done(), "{kind:?} {transport} {bytes}");
        }
    }
}

/// Property: simulation is deterministic — same seed, same event count,
/// same finish time; different op sizes change it.
#[test]
fn property_determinism() {
    let run = |bytes: u64| {
        let mut s = ClusterSim::new(fast_cfg());
        let id = s.submit(CollKind::AllReduce, bytes);
        s.run_to_idle(100_000_000);
        (s.ops[id.0].finished_at.unwrap().as_ns(), s.engine.dispatched())
    };
    assert_eq!(run(1 << 20), run(1 << 20));
    assert_ne!(run(1 << 20).0, run(2 << 20).0);
}

/// Property: failover never loses or duplicates chunks, across random
/// failure timings.
#[test]
fn property_failover_exactly_once_delivery() {
    let mut rng = Rng::new(0xFA11);
    for case in 0..FT_CASES {
        let mut cfg = fast_cfg();
        cfg.vccl.channels = 1;
        let mut s = ClusterSim::new(cfg);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        let down_at = SimTime::ns(rng.range(10_000, 3_000_000));
        s.inject_port_down(port, down_at);
        if rng.chance(0.5) {
            s.inject_port_up(port, down_at + SimTime::ms(rng.range(1, 400)));
        }
        let bytes = rng.range(1 << 20, 64 << 20);
        let id = s.submit_p2p(RankId(0), RankId(8), bytes);
        s.run_to_idle(100_000_000);
        assert!(s.ops[id.0].is_done(), "case {case}");
        // Exactly-once delivery survives failover, read off the roll-up
        // (§Perf L5: the transfer record itself is recycled at finish).
        // The wire may legitimately carry MORE chunks than were delivered
        // — exactly the rolled-back window retransmitted on the backup QP
        // — but never fewer; without a failover the counts are equal.
        let o = &s.ops[id.0];
        let wire: u64 = o.chan_rollup.iter().map(|c| c.chunks_wire).sum();
        let delivered: u64 = o.chan_rollup.iter().map(|c| c.chunks).sum();
        if s.stats.failovers == 0 {
            assert_eq!(wire, delivered, "case {case}: chunk loss/dup");
        } else {
            assert!(wire > delivered, "case {case}: failover must retransmit its window");
            // And the ridden retry window is visible as roll-up stall.
            let stall: u64 = o.chan_rollup.iter().map(|c| c.stall_ns).sum();
            assert!(stall > 0, "case {case}: failover must fold stall time");
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end stack scenarios
// ---------------------------------------------------------------------

/// The full reliability story in one scenario: train under a flap, fail
/// over, fail back, and keep the monitor healthy on unaffected ports.
#[test]
fn pipeline_failover_failback_with_monitor() {
    let mut cfg = fast_cfg();
    cfg.vccl.channels = 2;
    let pcfg = PipelineCfg::spread(&cfg, 4, 4);
    let mut p = PipelineSim::new(ClusterSim::new(cfg), pcfg);
    let port = p.sim.topo.primary_port(p.sim.topo.gpu_of_rank(RankId(4)));
    p.sim.inject_port_down(port, SimTime::ms(20));
    p.sim.inject_port_up(port, SimTime::ms(400));
    let r1 = p.run_iteration();
    assert!(!r1.hung && !r1.deadlocked);
    let r2 = p.run_iteration();
    assert!(!r2.hung);
    // After recovery the iteration time returns to (near) baseline.
    let mut base = PipelineSim::new(
        ClusterSim::new(fast_cfg()),
        PipelineCfg::spread(&fast_cfg(), 4, 4),
    );
    let rb = base.run_iteration();
    assert!(r2.iter_ns < rb.iter_ns * 12 / 10, "post-failback iter must normalize");
}

/// Transport ordering holds under every collective (SM-free ≤ NCCLX ≤ NCCL
/// in SM terms; completion times within sane factors).
#[test]
fn transports_complete_all_primitives_with_sane_ordering() {
    for kind in [CollKind::AllReduce, CollKind::AllToAll] {
        let mut times = Vec::new();
        for t in ["smfree", "ncclx", "kernel"] {
            let mut cfg = fast_cfg();
            cfg.set_key("vccl.transport", t).unwrap();
            let mut s = ClusterSim::new(cfg);
            let id = s.submit(kind, 8 << 20);
            s.run_to_idle(100_000_000);
            times.push(s.ops[id.0].finished_at.unwrap().as_ns());
        }
        // All within 3× of each other (the data path dominates).
        let min = *times.iter().min().unwrap();
        let max = *times.iter().max().unwrap();
        assert!(max < 3 * min, "{kind:?}: {times:?}");
    }
}

/// The monitor never cries wolf on a healthy cluster under heavy load.
#[test]
fn monitor_no_false_positives_under_load() {
    let mut s = ClusterSim::new(fast_cfg());
    for _ in 0..3 {
        let id = s.submit(CollKind::AllReduce, 32 << 20);
        s.run_until_op(id, 100_000_000);
    }
    let mon = s.monitor.as_ref().unwrap();
    let mut anomalies = 0;
    for port in 0..16 {
        anomalies += mon
            .verdicts(port)
            .iter()
            .filter(|(_, v)| *v == Verdict::NetworkAnomaly)
            .count();
    }
    assert_eq!(anomalies, 0, "healthy cluster must produce no network anomalies");
}

/// Env-var knobs round-trip through the whole stack.
#[test]
fn env_knobs_change_behaviour() {
    let mut cfg = Config::paper_defaults();
    vccl::config::apply_env(&mut cfg, |k| match k {
        "ICCL_IB_TIMEOUT" => Some("10".into()),
        "ICCL_IB_RETRY_CNT" => Some("2".into()),
        "VCCL_TRANSPORT" => Some("kernel".into()),
        _ => None,
    });
    assert_eq!(cfg.net.ib_timeout_exp, 10);
    assert_eq!(cfg.vccl.transport, Transport::Kernel);
    // The retry window derived from those knobs is what failover obeys.
    let window = cfg.net.retry_window_ns();
    assert_eq!(window, (4096.0 * 1024.0) as u64 * 2);
}

// ---------------------------------------------------------------------
// CLI / experiment-harness coverage
// ---------------------------------------------------------------------

/// Every experiment id the coordinator advertises must round-trip through
/// `parse_args` and produce a non-empty report from `run_experiment`
/// without panicking.
#[test]
fn every_experiment_id_parses_and_reports() {
    for (id, _) in EXPERIMENTS {
        let (cmd, _) = coordinator::parse_args(&["exp".to_string(), id.to_string()]).unwrap();
        assert!(matches!(cmd, Command::Exp { id: parsed } if parsed == *id));
    }
    // Debug builds skip the slowest timeline experiments (the un-optimized
    // simulator is ~10× slower and every allocation pass additionally
    // cross-checks against the global reference allocator; full coverage
    // is a release concern — same policy as `large_cluster_alltoall`).
    let heavy =
        ["fig13a", "fig18", "fig11", "fig13b", "scale64", "scale256", "scale512", "scale4k"];
    let cfg = Config::paper_defaults();
    for (id, _) in EXPERIMENTS {
        if cfg!(debug_assertions) && heavy.contains(id) {
            continue;
        }
        let report = coordinator::run_experiment(id, &cfg)
            .unwrap_or_else(|e| panic!("experiment {id} failed: {e}"));
        assert!(!report.trim().is_empty(), "experiment {id} returned an empty report");
        assert!(
            report.contains('|') || report.contains(':'),
            "experiment {id} produced no table:\n{report}"
        );
    }
    // `list` enumerates everything; unknown ids are a clean error, not a
    // panic.
    let listing = coordinator::run_experiment("list", &cfg).unwrap();
    for (id, _) in EXPERIMENTS {
        assert!(listing.contains(id), "listing is missing {id}");
    }
    assert!(coordinator::run_experiment("definitely-not-an-id", &cfg).is_err());
}

/// `vccl bench` must emit all six BENCH_*.json files with non-empty,
/// finite metric arrays (the acceptance gate for the perf trajectory).
#[test]
fn bench_emits_json_files_with_metrics() {
    let dir = std::env::temp_dir().join(format!("vccl_bench_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let paths = bench::run_bench(
        &Config::paper_defaults(),
        &dir,
        &bench::BenchOpts { quick: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(paths.len(), 6);
    for name in [
        "BENCH_p2p.json",
        "BENCH_failover.json",
        "BENCH_monitor.json",
        "BENCH_train.json",
        "BENCH_simcore.json",
        "BENCH_fabric.json",
    ] {
        let path = dir.join(name);
        assert!(paths.contains(&path), "missing {name}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"metrics\": ["), "{name} lacks a metrics array");
        assert!(text.contains("\"name\""), "{name} metrics array is empty");
        assert!(!text.contains("NaN"), "{name} contains non-finite values");
    }
    // Headline shape: VCCL rides through the port failure NCCL hangs on.
    let failover = std::fs::read_to_string(dir.join("BENCH_failover.json")).unwrap();
    assert!(failover.contains("failover.vccl.completed"));
    assert!(failover.contains("failover.nccl.hung"));
    // §Perf L3/L4/L5 trajectory: allocator flow-visits, RDMA QP-visits and
    // transfer-slab memory counters are all tracked.
    let simcore = std::fs::read_to_string(dir.join("BENCH_simcore.json")).unwrap();
    assert!(simcore.contains("simcore.alloc.visit_reduction_x"));
    assert!(simcore.contains("simcore.rdma.visit_reduction_x"));
    assert!(simcore.contains("simcore.mem.xfers_peak_live"));
    assert!(simcore.contains("simcore.mem.recycle_ratio_x"));
    // §Fault domains trajectory: plane-failover completeness and the RCA
    // trunk-to-switch attribution are tracked from a real traced run.
    let fabric = std::fs::read_to_string(dir.join("BENCH_fabric.json")).unwrap();
    assert!(fabric.contains("fabric.completeness"));
    assert!(fabric.contains("fabric.rca.trunk_precision"));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Flight recorder (trace subsystem)
// ---------------------------------------------------------------------

/// `vccl trace fig13a` captures the full §3.3 causal chain — PortDown →
/// FlowStalled → PointerMigrated → FlowResumed — in order, with monotone
/// timestamps, and the emitted Chrome trace JSON is valid and bit-identical
/// across two runs at the same seed.
#[test]
fn trace_fig13a_causal_chain() {
    if cfg!(debug_assertions) {
        return; // fig13a is one of the heavy timelines: release-only (same
                // policy as the experiment sweep above)
    }
    let dir = std::env::temp_dir().join(format!("vccl_trace_fig13a_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let run = |name: &str| {
        let path = dir.join(name);
        let run = coordinator::trace::run_traced(
            "fig13a",
            &Config::paper_defaults(),
            Some(path.as_path()),
        )
        .unwrap();
        let json = std::fs::read_to_string(&path).unwrap();
        vccl::trace::chrome::json_lint(&json)
            .unwrap_or_else(|e| panic!("emitted trace is not valid JSON: {e}"));
        (run, json)
    };
    let (r1, json1) = run("a.json");

    // The causal chain, in order, with monotone timestamps.
    let recs = &r1.records;
    assert_eq!(r1.dropped, 0, "fig13a must fit the trace-command ring");
    let pos = |k: &str| {
        recs.iter()
            .position(|r| r.ev.kind() == k)
            .unwrap_or_else(|| panic!("no {k} event in the fig13a trace"))
    };
    let chain = [
        pos("PortDown"),
        pos("FlowStalled"),
        pos("PointerMigrated"),
        pos("FlowResumed"),
    ];
    assert!(chain.windows(2).all(|w| w[0] < w[1]), "chain out of order: {chain:?}");
    assert!(
        chain.windows(2).all(|w| recs[w[0]].at <= recs[w[1]].at),
        "chain timestamps not monotone"
    );
    // The failover froze an incident snapshot containing the port flap.
    assert!(
        r1.incidents.iter().any(|i| i.name.starts_with("failover-conn")
            && i.events.iter().any(|e| e.ev.kind() == "PortDown")),
        "failover incident must capture the PortDown that caused it"
    );

    // Determinism: a second run at the same seed emits the identical file.
    let (_r2, json2) = run("b.json");
    assert_eq!(json1, json2, "trace JSON must be bit-identical across runs");
    let _ = std::fs::remove_dir_all(&dir);
}

/// With `trace.enabled=false` (the default) the recorder holds no sink and
/// allocates nothing — and `vccl bench` output is byte-identical whether
/// tracing is off or on (the recorder observes, it never schedules).
#[test]
fn trace_disabled_allocates_nothing_and_bench_identical() {
    // Zero-cost when disabled: no sink behind the handle.
    let s = ClusterSim::new(Config::paper_defaults());
    assert!(!s.tracer.enabled());
    assert!(s.tracer.sink().is_none(), "disabled tracer must not allocate a ring");

    let base = std::env::temp_dir().join(format!("vccl_trace_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let dir_off = base.join("off");
    let dir_on = base.join("on");
    let mut cfg_on = Config::paper_defaults();
    cfg_on.trace.enabled = true;
    cfg_on.trace.ring_capacity = 1 << 12;
    bench::run_bench(
        &Config::paper_defaults(),
        &dir_off,
        &bench::BenchOpts { quick: true, ..Default::default() },
    )
    .unwrap();
    bench::run_bench(&cfg_on, &dir_on, &bench::BenchOpts { quick: true, ..Default::default() })
        .unwrap();
    for name in ["BENCH_p2p.json", "BENCH_failover.json", "BENCH_monitor.json", "BENCH_train.json"]
    {
        let off = std::fs::read(dir_off.join(name)).unwrap();
        let on = std::fs::read(dir_on.join(name)).unwrap();
        assert_eq!(off, on, "{name} must be byte-identical with tracing on vs off");
    }
    let _ = std::fs::remove_dir_all(&base);
}

// ---------------------------------------------------------------------
// Incremental allocator (§Perf L3)
// ---------------------------------------------------------------------

/// Full-stack equivalence: an entire failover scenario — chunked transfer,
/// port death, retry window, failover, completion — driven once with the
/// incremental component-scoped allocator and once with the global
/// reference allocator must be *identical*: same finish time, same event
/// count, same failover count. (`set_reference_mode` only exists in
/// debug/test builds, so this test is debug-gated; the flow-level
/// randomized bit-equivalence test in `net::flow` runs everywhere.)
#[cfg(debug_assertions)]
#[test]
fn cluster_identical_under_reference_allocator() {
    let run = |reference: bool| {
        let mut cfg = fast_cfg();
        cfg.vccl.channels = 1;
        let mut s = ClusterSim::new(cfg);
        if reference {
            s.rdma.flows.set_reference_mode(true);
        }
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        // 256MB (~5.5s at line rate) so the 2ms port-down lands
        // mid-transfer and the full failover path runs.
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done());
        (
            s.ops[id.0].finished_at.unwrap().as_ns(),
            s.engine.dispatched(),
            s.stats.failovers,
        )
    };
    let inc = run(false);
    let refr = run(true);
    assert_eq!(inc, refr, "incremental vs reference cluster trajectories diverged");
    assert_eq!(inc.2, 1, "the scenario must actually fail over");
}

/// §Perf L4 mirror of the test above: a full failover scenario driven once
/// with the O(1) backlog counter + port→QP index and once with the
/// scan-based reference paths must be *identical* — same finish time, same
/// event count, same failover count, and (monitor on) same backlog values
/// fed to the pinpointer. (`RdmaNet::set_reference_mode` only exists in
/// debug/test builds, so this test is debug-gated; the randomized
/// bit-equivalence test in `net::rdma` runs everywhere.)
#[cfg(debug_assertions)]
#[test]
fn cluster_identical_under_reference_rdma_scans() {
    let run = |reference: bool| {
        let mut cfg = fast_cfg();
        cfg.vccl.channels = 1;
        let mut s = ClusterSim::new(cfg);
        if reference {
            s.rdma.set_reference_mode(true);
        }
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done());
        let mon = s.monitor.as_ref().expect("fast_cfg keeps the monitor on");
        (
            s.ops[id.0].finished_at.unwrap().as_ns(),
            s.engine.dispatched(),
            s.stats.failovers,
            mon.processed_wcs,
        )
    };
    let inc = run(false);
    let refr = run(true);
    assert_eq!(inc, refr, "incremental vs reference RDMA accounting diverged");
    assert_eq!(inc.2, 1, "the scenario must actually fail over");
}

/// The allocator's work counters show the component win on a real
/// collective: far fewer flow visits than the global floor.
#[test]
fn allocator_visits_stay_below_global_floor() {
    let mut s = ClusterSim::new(fast_cfg());
    let id = s.submit(CollKind::AllReduce, 8 << 20);
    s.run_to_idle(100_000_000);
    assert!(s.ops[id.0].is_done());
    let a = s.rdma.flows.alloc_stats();
    assert!(a.changes > 100, "changes={}", a.changes);
    assert!(
        a.flow_visits < a.global_floor,
        "incremental visits {} must undercut the global floor {}",
        a.flow_visits,
        a.global_floor
    );
}

// ---------------------------------------------------------------------
// Config-driven fabric rates
// ---------------------------------------------------------------------

/// `net.link_gbps` / `gpu.nvlink_gbps` reach the fabric: halving the line
/// rate halves single-flow goodput (previously the fabric used hard-coded
/// build rates and these keys were silently ignored).
#[test]
fn link_rate_config_scales_goodput() {
    let inter_bw = |gbps: f64| {
        let mut cfg = fast_cfg();
        cfg.net.link_gbps = gbps;
        let mut s = ClusterSim::new(cfg);
        let (_, op) = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        op.algbw_gbps().unwrap()
    };
    let full = inter_bw(400.0);
    let half = inter_bw(200.0);
    assert!(half < 200.0, "half-rate goodput must respect the 200 Gbps line: {half}");
    let ratio = full / half;
    assert!((ratio - 2.0).abs() < 0.1, "expected ~2x, got {ratio} ({full} vs {half})");

    let intra_bw = |gbps: f64| {
        let mut cfg = fast_cfg();
        cfg.gpu.nvlink_gbps = gbps;
        let mut s = ClusterSim::new(cfg);
        let (_, op) = s.run_p2p(RankId(0), RankId(1), ByteSize::mb(64).0);
        op.algbw_gbps().unwrap()
    };
    let nv_full = intra_bw(3600.0);
    let nv_half = intra_bw(1800.0);
    let nv_ratio = nv_full / nv_half;
    assert!((1.6..2.2).contains(&nv_ratio), "expected ~2x NVLink scaling, got {nv_ratio}");
}

// ---------------------------------------------------------------------
// Bounded transfer lifecycle (§Perf L5)
// ---------------------------------------------------------------------

/// §Perf L5: transfer bookkeeping is O(active) on a full collective — the
/// slab recycles completed records, every per-transfer map drains, and the
/// per-op roll-ups carry the figures the retired records used to.
#[test]
fn transfer_slab_bounds_live_records() {
    let mut s = ClusterSim::new(fast_cfg());
    let id = s.submit(CollKind::AllReduce, 8 << 20);
    s.run_to_idle(100_000_000);
    assert!(s.ops[id.0].is_done());
    let m = s.xfers.mem_stats();
    assert!(m.created > 500, "{m:?}");
    assert_eq!(m.live, 0, "all transfers retire at quiescence: {m:?}");
    assert_eq!(m.created, m.retired);
    assert!(m.high_water * 4 < m.created, "peak live must stay far below created: {m:?}");
    assert!(m.slots_resident <= m.high_water, "resident slots cap at the live peak: {m:?}");
    assert_eq!(s.intra_flow_count(), 0, "flow→transfer map must drain");
    assert_eq!(s.rdma.flow_owner_count(), 0, "flow→WR map must drain");
    // The roll-up preserves the op's accounting across recycling: no
    // failure was injected, so wire chunks == delivered chunks exactly.
    let o = &s.ops[id.0];
    let wire: u64 = o.chan_rollup.iter().map(|c| c.chunks_wire).sum();
    let delivered: u64 = o.chan_rollup.iter().map(|c| c.chunks).sum();
    assert_eq!(wire, delivered, "wire/delivered chunk conservation must hold");
    assert!(o.chan_rollup.iter().map(|c| c.bytes).sum::<u64>() > 0);
}

/// Closes ROADMAP's leftover PR-3 item (§Perf L5 satellite): a fig18-style
/// progressive multi-failure resilience sweep at 64 nodes. The rail-0
/// boundary ports of nodes 0, 1, 2 die at 30 ms intervals under
/// continuous 2-channel ring-AllReduce traffic and all heal at 120 ms.
/// Per-phase cluster goodput — read off the bounded, window-bucketed
/// `monitor::PortTraffic` stats, NOT a per-chunk log — must degrade
/// monotonically through the failure phases and recover after failback.
/// Release-only: ~6M chunked transfers (same policy as scale64/scale256).
#[test]
fn fig18_progressive_failures_at_scale64() {
    if cfg!(debug_assertions) {
        return;
    }
    let mut cfg = Config::scale64();
    cfg.vccl.channels = 2; // rails 0 and 1 carry traffic; failovers land on rail 1
    cfg.net.qp_warmup_ns = 20_000_000; // primaries are warm before the 120 ms heal
    let mut s = ClusterSim::new(cfg);
    let phase_ms = 30u64;
    // Victims: the rail-0 inter-node boundary port of nodes 0, 1, 2 —
    // each failover shares the node's rail-1 NIC with channel-1 traffic,
    // so degradation persists while the port is down (Fig 18's shape).
    for i in 0..3u64 {
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(i as usize * 8)));
        s.inject_port_down(port, SimTime::ms(phase_ms * (i + 1)));
        s.inject_port_up(port, SimTime::ms(phase_ms * 4));
    }
    let horizon = SimTime::ms(phase_ms * 6);
    while s.now() < horizon {
        let id = s.submit(CollKind::AllReduce, ByteSize::gb(1).0);
        assert!(
            s.run_until_op(id, 400_000_000),
            "allreduce under progressive failures must complete"
        );
    }
    assert!(s.stats.failovers >= 3, "each victim must force at least one failover");
    assert_eq!(
        s.stats.failbacks, s.stats.failovers,
        "every failed-over connection must return to its primary"
    );
    // Per-phase inter-node goodput from the bounded PortTraffic buckets
    // (phase bounds are multiples of the 10 ms aggregation window → exact).
    let t = |ph: u64| {
        s.stats
            .port_traffic
            .bytes_between(ph * phase_ms * 1_000_000, (ph + 1) * phase_ms * 1_000_000)
    };
    let (t0, t1, t2, t3, t5) = (t(0), t(1), t(2), t(3), t(5));
    assert!(t0 > 0, "healthy phase must move bytes");
    // Monotone degradation: the first failure halves the bottleneck rail
    // (stall + shared backup rail); later failures never improve things.
    // Small tolerance — like the paper's Fig 18, phases 2/3 plateau once
    // the bottleneck is already doubled (450→350→190→190 in the paper).
    assert!(t1 * 10 < t0 * 8, "first failure must cost >20%: t0={t0} t1={t1}");
    assert!(t2 * 100 <= t1 * 105, "degradation must be monotone: t1={t1} t2={t2}");
    assert!(t3 * 100 <= t2 * 105, "degradation must be monotone: t2={t2} t3={t3}");
    // Recovery: after the 120 ms heal + failback, goodput returns.
    assert!(t5 * 5 > t3 * 6, "failback must recover throughput: t3={t3} t5={t5}");
    assert!(t5 * 100 > t0 * 85, "recovered phase must approach baseline: t0={t0} t5={t5}");
    // And the §Perf L5 slab kept the whole sweep O(active).
    let m = s.xfers.mem_stats();
    assert!(m.created > 1_000_000, "sweep too small: {m:?}");
    assert!(m.high_water * 100 < m.created, "≥100× recycling at 64 nodes: {m:?}");
}

// ---------------------------------------------------------------------
// Causal root-cause engine (vccl rca)
// ---------------------------------------------------------------------

fn metric(bench: &vccl::metrics::BenchReport, name: &str) -> f64 {
    bench
        .metrics
        .iter()
        .find(|m| m.name == name)
        .unwrap_or_else(|| panic!("missing metric {name}"))
        .value
}

/// The acceptance gate: `vccl rca fig15` (single-victim pinpointing) must
/// diagnose every injected flap — recall ≥ 0.9 and precision ≥ 0.9 read
/// off the same BENCH_rca.json rows CI gates on — and the full rendered
/// diagnosis must be bit-identical across two runs at the same seed.
#[test]
fn rca_fig15_meets_gates_and_is_bit_identical() {
    let cfg = Config::paper_defaults();
    let run = || coordinator::rca::run_rca("fig15", &cfg, None).unwrap();
    let (text, bench) = run();
    assert!(metric(&bench, "rca.fig15.recall") >= 0.9, "{text}");
    assert!(metric(&bench, "rca.fig15.precision") >= 0.9, "{text}");
    assert_eq!(metric(&bench, "rca.fig15.injected"), 4.0);
    assert!(text.contains("causal chain"), "{text}");
    assert!(text.contains("ground truth — fig15"), "{text}");
    let (text2, bench2) = run();
    assert_eq!(text, text2, "rca output must be bit-identical across runs");
    assert_eq!(bench.metrics, bench2.metrics);
}

/// `vccl trace <id> --diff`: two traced runs of a deterministic experiment
/// produce an identical event stream, and the rendered delta says so.
/// (table5 is the cheap sim-backed experiment the trace tests use.)
#[test]
fn trace_diff_verdict_is_identical_for_same_seed() {
    let (text, identical) =
        coordinator::trace::run_traced_diff("table5", &Config::paper_defaults()).unwrap();
    assert!(identical, "{text}");
    assert!(text.contains("IDENTICAL"), "{text}");
    assert!(text.contains("event kind"), "diff must break counts down by kind: {text}");
}

/// fig18 (progressive multi-victim) and scale64 (flaps + monitored
/// degrade) end-to-end: soft gates — multi-victim walks share symptom
/// entities so some victims may rank second, but most must be recalled
/// and nothing may be mis-attributed. The fig18 capture lands inside the
/// fourth victim's retry window, so the hung op surfaces as an
/// `op-deadline` symptom and the frozen incidents carry live in-flight
/// transfers (`xfers.live()` at freeze time). Release-only: ~GBs of
/// chunked transfer (same policy as the scale64/fig18 sweeps above).
#[test]
fn rca_multi_victim_scenarios_meet_soft_gates() {
    if cfg!(debug_assertions) {
        return;
    }
    let cfg = Config::paper_defaults();
    let (text, bench) = coordinator::rca::run_rca("fig18", &cfg, None).unwrap();
    assert_eq!(metric(&bench, "rca.fig18.injected"), 4.0);
    assert!(metric(&bench, "rca.fig18.recall") >= 0.6, "{text}");
    assert!(metric(&bench, "rca.fig18.precision") >= 0.9, "{text}");
    assert!(text.contains("op-deadline"), "the hung op must surface as a symptom: {text}");

    let sc = coordinator::rca::fig18_scenario(&cfg);
    assert!(!sc.incidents.is_empty(), "fig18 freezes failover incidents");
    assert!(
        sc.incidents.iter().any(|i| i.live_total > 0 && !i.live_xfers.is_empty()),
        "incident snapshots must carry live in-flight transfers"
    );
    // Verdict-triggered port identification is structural, not parsed.
    for inc in &sc.incidents {
        if let Some(p) = inc.port() {
            assert!(p < 128, "port ordinal {p} out of range for 2 nodes");
        }
    }

    let (text, bench) = coordinator::rca::run_rca("scale64", &cfg, None).unwrap();
    assert_eq!(metric(&bench, "rca.scale64.injected"), 3.0);
    assert!(metric(&bench, "rca.scale64.recall") >= 0.6, "{text}");
    assert!(metric(&bench, "rca.scale64.precision") >= 0.9, "{text}");
}

/// Large-scale smoke: an 8-node (64-GPU) alltoall completes and stays
/// deterministic (the §Perf events/s budget is what makes this fast).
#[test]
fn large_cluster_alltoall() {
    if cfg!(debug_assertions) {
        return; // release-only: 4k transfers through the un-optimized build
    }
    let mut cfg = fast_cfg();
    cfg.topo.num_nodes = 8;
    cfg.vccl.channels = 1;
    let mut s = ClusterSim::new(cfg);
    let id = s.submit(CollKind::AllToAll, ByteSize::mb(4).0);
    s.run_to_idle(400_000_000);
    assert!(s.ops[id.0].is_done());
    assert!(s.stats.wire_bytes > 0);
}
