//! Cross-module integration tests: the full stack (topology → net → gpu →
//! ccl → fault → monitor → pipeline) driven through the public API, plus
//! RNG-driven property sweeps (proptest is unavailable in the offline
//! vendored build; these use seeded exhaustive/random case generation).

use vccl::ccl::{ClusterSim, CollKind};
use vccl::config::{Config, Transport};
use vccl::coordinator::{self, bench, Command, EXPERIMENTS};
use vccl::monitor::Verdict;
use vccl::pipeline::{PipelineCfg, PipelineSim};
use vccl::sim::SimTime;
use vccl::topology::RankId;
use vccl::util::{ByteSize, Rng};

/// Debug builds run the same properties with fewer random cases (the
/// un-optimized simulator is ~10× slower; coverage is a release concern).
const CASES: usize = if cfg!(debug_assertions) { 5 } else { 30 };
const FT_CASES: usize = if cfg!(debug_assertions) { 4 } else { 20 };

fn fast_cfg() -> Config {
    let mut c = Config::paper_defaults();
    c.net.ib_timeout_exp = 10;
    c.net.ib_retry_cnt = 2;
    c.net.qp_warmup_ns = 50_000_000;
    c.vccl.channels = 2;
    c
}

// ---------------------------------------------------------------------
// Conservation / correctness invariants
// ---------------------------------------------------------------------

/// Property: every submitted byte is delivered exactly once, for random
/// sizes, random (src,dst) pairs and every transport.
#[test]
fn property_p2p_conserves_bytes() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        let transport = *rng.choose(&["kernel", "ncclx", "smfree"]);
        let mut cfg = fast_cfg();
        cfg.set_key("vccl.transport", transport).unwrap();
        let mut s = ClusterSim::new(cfg);
        let n = s.topo.num_ranks();
        let src = RankId(rng.below(n as u64) as usize);
        let mut dst = RankId(rng.below(n as u64) as usize);
        if dst == src {
            dst = RankId((src.0 + 1) % n);
        }
        let bytes = rng.range(1, 8 << 20);
        let id = s.submit_p2p(src, dst, bytes);
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done(), "case {case}: {src}->{dst} {bytes}B {transport}");
        // Chunk accounting: posted == transmitted == acked == total.
        for x in &s.xfers {
            assert_eq!(x.send.acked, x.chunks_total, "case {case}");
            assert!(x.send.invariant_ok());
        }
    }
}

/// Property: collectives complete for every kind × transport × size combo.
#[test]
fn property_collectives_always_complete() {
    let kinds = [CollKind::AllReduce, CollKind::AllGather, CollKind::ReduceScatter,
                 CollKind::AllToAll];
    let mut rng = Rng::new(0xC0FFEE);
    for &kind in &kinds {
        for transport in ["kernel", "smfree"] {
            let mut cfg = fast_cfg();
            cfg.set_key("vccl.transport", transport).unwrap();
            let mut s = ClusterSim::new(cfg);
            let bytes = rng.range(1 << 16, 16 << 20);
            let id = s.submit(kind, bytes);
            s.run_to_idle(100_000_000);
            assert!(s.ops[id.0].is_done(), "{kind:?} {transport} {bytes}");
        }
    }
}

/// Property: simulation is deterministic — same seed, same event count,
/// same finish time; different op sizes change it.
#[test]
fn property_determinism() {
    let run = |bytes: u64| {
        let mut s = ClusterSim::new(fast_cfg());
        let id = s.submit(CollKind::AllReduce, bytes);
        s.run_to_idle(100_000_000);
        (s.ops[id.0].finished_at.unwrap().as_ns(), s.engine.dispatched())
    };
    assert_eq!(run(1 << 20), run(1 << 20));
    assert_ne!(run(1 << 20).0, run(2 << 20).0);
}

/// Property: failover never loses or duplicates chunks, across random
/// failure timings.
#[test]
fn property_failover_exactly_once_delivery() {
    let mut rng = Rng::new(0xFA11);
    for case in 0..FT_CASES {
        let mut cfg = fast_cfg();
        cfg.vccl.channels = 1;
        let mut s = ClusterSim::new(cfg);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        let down_at = SimTime::ns(rng.range(10_000, 3_000_000));
        s.inject_port_down(port, down_at);
        if rng.chance(0.5) {
            s.inject_port_up(port, down_at + SimTime::ms(rng.range(1, 400)));
        }
        let bytes = rng.range(1 << 20, 64 << 20);
        let id = s.submit_p2p(RankId(0), RankId(8), bytes);
        s.run_to_idle(100_000_000);
        assert!(s.ops[id.0].is_done(), "case {case}");
        let x = &s.xfers[0];
        assert_eq!(x.send.acked, x.chunks_total, "case {case}: chunk loss/dup");
    }
}

// ---------------------------------------------------------------------
// End-to-end stack scenarios
// ---------------------------------------------------------------------

/// The full reliability story in one scenario: train under a flap, fail
/// over, fail back, and keep the monitor healthy on unaffected ports.
#[test]
fn pipeline_failover_failback_with_monitor() {
    let mut cfg = fast_cfg();
    cfg.vccl.channels = 2;
    let pcfg = PipelineCfg::spread(&cfg, 4, 4);
    let mut p = PipelineSim::new(ClusterSim::new(cfg), pcfg);
    let port = p.sim.topo.primary_port(p.sim.topo.gpu_of_rank(RankId(4)));
    p.sim.inject_port_down(port, SimTime::ms(20));
    p.sim.inject_port_up(port, SimTime::ms(400));
    let r1 = p.run_iteration();
    assert!(!r1.hung && !r1.deadlocked);
    let r2 = p.run_iteration();
    assert!(!r2.hung);
    // After recovery the iteration time returns to (near) baseline.
    let mut base = PipelineSim::new(
        ClusterSim::new(fast_cfg()),
        PipelineCfg::spread(&fast_cfg(), 4, 4),
    );
    let rb = base.run_iteration();
    assert!(r2.iter_ns < rb.iter_ns * 12 / 10, "post-failback iter must normalize");
}

/// Transport ordering holds under every collective (SM-free ≤ NCCLX ≤ NCCL
/// in SM terms; completion times within sane factors).
#[test]
fn transports_complete_all_primitives_with_sane_ordering() {
    for kind in [CollKind::AllReduce, CollKind::AllToAll] {
        let mut times = Vec::new();
        for t in ["smfree", "ncclx", "kernel"] {
            let mut cfg = fast_cfg();
            cfg.set_key("vccl.transport", t).unwrap();
            let mut s = ClusterSim::new(cfg);
            let id = s.submit(kind, 8 << 20);
            s.run_to_idle(100_000_000);
            times.push(s.ops[id.0].finished_at.unwrap().as_ns());
        }
        // All within 3× of each other (the data path dominates).
        let min = *times.iter().min().unwrap();
        let max = *times.iter().max().unwrap();
        assert!(max < 3 * min, "{kind:?}: {times:?}");
    }
}

/// The monitor never cries wolf on a healthy cluster under heavy load.
#[test]
fn monitor_no_false_positives_under_load() {
    let mut s = ClusterSim::new(fast_cfg());
    for _ in 0..3 {
        let id = s.submit(CollKind::AllReduce, 32 << 20);
        s.run_until_op(id, 100_000_000);
    }
    let mon = s.monitor.as_ref().unwrap();
    let mut anomalies = 0;
    for port in 0..16 {
        anomalies += mon
            .verdicts(port)
            .iter()
            .filter(|(_, v)| *v == Verdict::NetworkAnomaly)
            .count();
    }
    assert_eq!(anomalies, 0, "healthy cluster must produce no network anomalies");
}

/// Env-var knobs round-trip through the whole stack.
#[test]
fn env_knobs_change_behaviour() {
    let mut cfg = Config::paper_defaults();
    vccl::config::apply_env(&mut cfg, |k| match k {
        "ICCL_IB_TIMEOUT" => Some("10".into()),
        "ICCL_IB_RETRY_CNT" => Some("2".into()),
        "VCCL_TRANSPORT" => Some("kernel".into()),
        _ => None,
    });
    assert_eq!(cfg.net.ib_timeout_exp, 10);
    assert_eq!(cfg.vccl.transport, Transport::Kernel);
    // The retry window derived from those knobs is what failover obeys.
    let window = cfg.net.retry_window_ns();
    assert_eq!(window, (4096.0 * 1024.0) as u64 * 2);
}

// ---------------------------------------------------------------------
// CLI / experiment-harness coverage
// ---------------------------------------------------------------------

/// Every experiment id the coordinator advertises must round-trip through
/// `parse_args` and produce a non-empty report from `run_experiment`
/// without panicking.
#[test]
fn every_experiment_id_parses_and_reports() {
    for (id, _) in EXPERIMENTS {
        let (cmd, _) = coordinator::parse_args(&["exp".to_string(), id.to_string()]).unwrap();
        assert!(matches!(cmd, Command::Exp { id: parsed } if parsed == *id));
    }
    // Debug builds skip the four slowest timeline experiments (the
    // un-optimized simulator is ~10× slower; full coverage is a release
    // concern — same policy as `large_cluster_alltoall`).
    let heavy = ["fig13a", "fig18", "fig11", "fig13b"];
    let cfg = Config::paper_defaults();
    for (id, _) in EXPERIMENTS {
        if cfg!(debug_assertions) && heavy.contains(id) {
            continue;
        }
        let report = coordinator::run_experiment(id, &cfg)
            .unwrap_or_else(|e| panic!("experiment {id} failed: {e}"));
        assert!(!report.trim().is_empty(), "experiment {id} returned an empty report");
        assert!(
            report.contains('|') || report.contains(':'),
            "experiment {id} produced no table:\n{report}"
        );
    }
    // `list` enumerates everything; unknown ids are a clean error, not a
    // panic.
    let listing = coordinator::run_experiment("list", &cfg).unwrap();
    for (id, _) in EXPERIMENTS {
        assert!(listing.contains(id), "listing is missing {id}");
    }
    assert!(coordinator::run_experiment("definitely-not-an-id", &cfg).is_err());
}

/// `vccl bench` must emit all four BENCH_*.json files with non-empty,
/// finite metric arrays (the acceptance gate for the perf trajectory).
#[test]
fn bench_emits_four_json_files_with_metrics() {
    let dir = std::env::temp_dir().join(format!("vccl_bench_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let paths =
        bench::run_bench(&Config::paper_defaults(), &dir, &bench::BenchOpts { quick: true })
            .unwrap();
    assert_eq!(paths.len(), 4);
    for name in ["BENCH_p2p.json", "BENCH_failover.json", "BENCH_monitor.json", "BENCH_train.json"]
    {
        let path = dir.join(name);
        assert!(paths.contains(&path), "missing {name}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"metrics\": ["), "{name} lacks a metrics array");
        assert!(text.contains("\"name\""), "{name} metrics array is empty");
        assert!(!text.contains("NaN"), "{name} contains non-finite values");
    }
    // Headline shape: VCCL rides through the port failure NCCL hangs on.
    let failover = std::fs::read_to_string(dir.join("BENCH_failover.json")).unwrap();
    assert!(failover.contains("failover.vccl.completed"));
    assert!(failover.contains("failover.nccl.hung"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Large-scale smoke: an 8-node (64-GPU) alltoall completes and stays
/// deterministic (the §Perf events/s budget is what makes this fast).
#[test]
fn large_cluster_alltoall() {
    if cfg!(debug_assertions) {
        return; // release-only: 4k transfers through the un-optimized build
    }
    let mut cfg = fast_cfg();
    cfg.topo.num_nodes = 8;
    cfg.vccl.channels = 1;
    let mut s = ClusterSim::new(cfg);
    let id = s.submit(CollKind::AllToAll, ByteSize::mb(4).0);
    s.run_to_idle(400_000_000);
    assert!(s.ops[id.0].is_done());
    assert!(s.stats.wire_bytes > 0);
}
