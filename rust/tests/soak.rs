//! §Soak integration suite: checkpoint/resume bit-identity, fault-schedule
//! determinism, monitor grading properties and bounded-vs-retain-all
//! equivalence — everything the time-compressed soak harness promises,
//! exercised through the public API.

use vccl::ccl::{ClusterSim, CollKind};
use vccl::config::Config;
use vccl::sim::SimTime;
use vccl::soak::{FaultClock, SoakHarness, SoakParams, BURST_PERIOD_NS};
use vccl::topology::RankId;
use vccl::util::Rng;

/// Debug builds run fewer randomized cases (the un-optimized simulator is
/// ~10× slower; breadth is a release concern — same policy as
/// tests/integration.rs).
const CASES: u64 = if cfg!(debug_assertions) { 2 } else { 6 };

fn params(bursts: u64, flap_weight: u32, degrade_weight: u32) -> SoakParams {
    SoakParams {
        period_ns: BURST_PERIOD_NS,
        mtbf_ns: 90_000_000_000, // 1.5 simulated minutes: ~2 faults / 3 bursts
        mttr_ns: 30_000_000_000,
        bursts_total: bursts,
        checkpoint_every: 0,
        flap_weight,
        degrade_weight,
        trunk_weight: 0,
        switch_weight: 0,
        node_weight: 0,
        allreduce: true,
    }
}

fn goodput_rollup(sim: &ClusterSim) -> u64 {
    sim.ops.iter().map(|o| o.chan_rollup.iter().map(|c| c.bytes).sum::<u64>()).sum()
}

// ---------------------------------------------------------------------
// Satellite: randomized checkpoint/resume bit-identity
// ---------------------------------------------------------------------

/// The headline §Soak contract: interrupt a soak at ANY burst boundary,
/// restore into a fresh process-equivalent harness, and the final report —
/// and the underlying simulation — are bit-identical to the uninterrupted
/// run. Seeds and interrupt points are randomized.
#[test]
fn checkpoint_resume_bit_identity_randomized() {
    let mut pick = Rng::new(0xB17_1DE4);
    for case in 0..CASES {
        let mut cfg = Config::soak_defaults();
        cfg.seed = 0x5CC1 + case * 7919;
        let bursts = 4 + pick.below(2); // 4-5 bursts per case
        let cut = 1 + pick.below(bursts - 1); // interrupt strictly mid-soak

        let mut reference = SoakHarness::with_params(cfg.clone(), params(bursts, 1, 1));
        while !reference.done() {
            reference.run_burst();
        }
        assert!(!reference.hung(), "case {case}: soak must not hang");
        let want = reference.report().to_bench().to_json();

        let mut first = SoakHarness::with_params(cfg.clone(), params(bursts, 1, 1));
        for _ in 0..cut {
            first.run_burst();
        }
        let ckpt = first.checkpoint();
        drop(first);

        let mut resumed = SoakHarness::restore_with_params(cfg, params(bursts, 1, 1), &ckpt)
            .expect("restore");
        // Restoring is a fixed point of checkpointing.
        assert_eq!(resumed.checkpoint(), ckpt, "case {case}: re-checkpoint drifted");
        while !resumed.done() {
            resumed.run_burst();
        }
        let got = resumed.report().to_bench().to_json();
        assert_eq!(
            got, want,
            "case {case} (seed {}, cut at burst {cut}/{bursts}): resumed BENCH_soak \
             diverged from the uninterrupted run",
            0x5CC1 + case * 7919
        );
        assert_eq!(resumed.sim.now(), reference.sim.now(), "case {case}: clocks diverged");
        assert_eq!(
            resumed.sim.checkpoint(),
            reference.sim.checkpoint(),
            "case {case}: final sim states diverged"
        );
    }
}

// ---------------------------------------------------------------------
// Satellite: seeded fault-scheduler determinism
// ---------------------------------------------------------------------

/// Same seed ⇒ identical fault schedule (arrival times AND the kind /
/// target / jitter draws that follow, witnessed through the injected-fault
/// counters and the full report); different seed ⇒ a different schedule.
#[test]
fn fault_schedule_is_seed_deterministic() {
    let mk = |seed: u64| {
        let mut cfg = Config::soak_defaults();
        cfg.seed = seed;
        let mut h = SoakHarness::with_params(cfg, params(4, 1, 1));
        while !h.done() {
            h.run_burst();
        }
        h.report()
    };
    let a = mk(1);
    let b = mk(1);
    assert_eq!(a.to_bench().to_json(), b.to_bench().to_json());
    assert!(a.flaps_injected + a.degrades_injected >= 1, "MTBF of 1.5 bursts must fault");

    // A different seed moves the schedule. Arrival times are continuous
    // (exponential draws), so compare those rather than coarse counts.
    let c1 = FaultClock::new(1, 90e9, 0);
    let c2 = FaultClock::new(2, 90e9, 0);
    assert_ne!(c1.next_at_ns(), c2.next_at_ns());
}

/// The empirical inter-arrival mean of the fault clock converges to the
/// configured MTBF (the schedule really is Poisson at the requested rate).
#[test]
fn fault_interarrival_mean_matches_mtbf() {
    for (seed, mtbf) in [(11u64, 3.6e12), (12, 0.9e12)] {
        let mut c = FaultClock::new(seed, mtbf, 0);
        let n = 20_000u64;
        let mut prev = 0u64;
        let mut sum = 0u64;
        for _ in 0..n {
            let at = c.advance();
            sum += at - prev;
            prev = at;
        }
        let mean = sum as f64 / n as f64;
        let err = (mean - mtbf).abs() / mtbf;
        assert!(err < 0.05, "seed {seed}: mean {mean:.3e} vs MTBF {mtbf:.3e} ({err:.3})");
    }
}

// ---------------------------------------------------------------------
// Satellite: bounded monitor ≡ retain-all monitor under the soak
// ---------------------------------------------------------------------

/// The §Soak memory bounding must not change a single verdict: a soak run
/// with the monitor's full retain-all reference logs produces the exact
/// same verdict counts — and the same final report — as the bounded
/// default. (Reference mode is compiled under debug/ref-alloc only.)
#[cfg(debug_assertions)]
#[test]
fn bounded_monitor_matches_retain_all_under_soak() {
    let run = |retain_all: bool| {
        let cfg = Config::soak_defaults();
        let mut h = SoakHarness::with_params(cfg, params(4, 0, 1)); // degrade-only
        if retain_all {
            h.sim.monitor.as_mut().expect("soak preset keeps the monitor on").set_retain_all(true);
        }
        while !h.done() {
            h.run_burst();
        }
        let counts: Vec<[u64; 3]> = {
            let mon = h.sim.monitor.as_ref().unwrap();
            mon.active_ports().into_iter().map(|p| mon.verdict_counts(p)).collect()
        };
        (h.report().to_bench().to_json(), counts)
    };
    let (bounded_json, bounded_counts) = run(false);
    let (ref_json, ref_counts) = run(true);
    assert_eq!(bounded_json, ref_json);
    assert_eq!(bounded_counts, ref_counts);
    assert!(bounded_counts.iter().any(|c| c[1] + c[2] > 0), "degrades must be flagged");
}

// ---------------------------------------------------------------------
// Satellite: soak-report properties
// ---------------------------------------------------------------------

/// Availability is a fraction, and with fault tolerance on it is exactly
/// 1.0 — every op of every burst completes despite the fault schedule.
#[test]
fn availability_is_one_with_fault_tolerance() {
    let mut h = SoakHarness::with_params(Config::soak_defaults(), params(5, 1, 1));
    while !h.done() {
        h.run_burst();
    }
    let r = h.report();
    assert!((0.0..=1.0).contains(&r.availability));
    assert_eq!(r.availability, 1.0);
    assert_eq!(r.ops_submitted, 5 * 9, "1 AllReduce + 8 P2Ps per burst");
    assert_eq!(r.ops_completed, r.ops_submitted);
}

/// Flap accounting: every injected flap causes exactly one failover, and
/// (MTTR + warm-up < period) exactly one failback before the burst ends.
#[test]
fn every_flap_fails_over_and_back() {
    for case in 0..CASES {
        let mut cfg = Config::soak_defaults();
        cfg.seed = 0xF1A9 + case;
        let mut h = SoakHarness::with_params(cfg, params(5, 1, 0)); // flap-only
        while !h.done() {
            h.run_burst();
        }
        let r = h.report();
        assert!(r.flaps_injected >= 1, "case {case}: schedule produced no flaps");
        assert_eq!(r.degrades_injected, 0);
        assert_eq!(r.failovers, r.flaps_injected, "case {case}");
        assert_eq!(r.failbacks, r.flaps_injected, "case {case}");
    }
}

/// Degrade grading: with MTTR ≫ the monitor's detection window, the
/// verdict confusion matrix is perfect — precision and recall both 1.0,
/// and every injected degrade is detected before it heals.
#[test]
fn monitor_grading_is_perfect_on_degrades() {
    for case in 0..CASES {
        let mut cfg = Config::soak_defaults();
        cfg.seed = 0xDE9 + case * 31;
        let mut h = SoakHarness::with_params(cfg, params(5, 0, 1)); // degrade-only
        while !h.done() {
            h.run_burst();
        }
        let r = h.report();
        assert!(r.degrades_injected >= 1, "case {case}: schedule produced no degrades");
        assert_eq!(r.flaps_injected, 0);
        assert_eq!(r.precision(), 1.0, "case {case}: fp={}", r.fp);
        assert_eq!(r.recall(), 1.0, "case {case}: fn={}", r.fn_);
        assert_eq!(r.degrades_detected, r.degrades_injected, "case {case}");
        assert!(r.tp >= r.degrades_injected, "≥1 flagged (port, burst) cell per degrade");
        assert!(r.tn > 0, "fault-free cells must grade as true negatives");
    }
}

/// Goodput conservation: the harness' per-op accumulation equals the sum
/// of the simulator's own per-channel roll-ups, and wire bytes (which
/// include breakpoint retransmissions) are never below goodput.
#[test]
fn goodput_matches_chan_rollups() {
    let mut h = SoakHarness::with_params(Config::soak_defaults(), params(4, 1, 1));
    while !h.done() {
        h.run_burst();
    }
    let r = h.report();
    assert!(r.goodput_bytes > 0);
    assert_eq!(r.goodput_bytes, goodput_rollup(&h.sim));
    assert!(r.wire_bytes >= r.goodput_bytes);
}

// ---------------------------------------------------------------------
// Satellite: randomized node-crash fault tolerance (§Elastic)
// ---------------------------------------------------------------------

/// Node-crash soak property, randomized over seeds: no op is ever lost —
/// every burst's ops complete despite elastic shrinks — and each crash
/// produces exactly one shrink and exactly one rejoin (MTTR < period, so
/// every victim returns inside its own burst and the cluster ends whole).
#[test]
fn node_crash_soak_never_loses_an_op() {
    for case in 0..CASES {
        let mut cfg = Config::soak_defaults();
        cfg.seed = 0xE1A5 + case * 101;
        let mut p = params(5, 0, 0); // crash-only schedule
        p.node_weight = 1;
        let mut h = SoakHarness::with_params(cfg, p);
        while !h.done() {
            h.run_burst();
        }
        assert!(!h.hung(), "case {case}: a crash stranded an op");
        let r = h.report();
        assert_eq!(r.availability, 1.0, "case {case}: an op was lost to a crash");
        assert!(r.node_crashes_injected >= 1, "case {case}: schedule produced no crashes");
        assert_eq!(r.flaps_injected, 0, "case {case}");
        assert_eq!(r.degrades_injected, 0, "case {case}");
        assert_eq!(r.elastic_shrinks, r.node_crashes_injected, "case {case}");
        assert_eq!(r.elastic_rejoins, r.node_crashes_injected, "case {case}");
        assert!(h.sim.dead_nodes.iter().all(|d| !d), "case {case}: every victim rejoined");
    }
}

/// Non-crossing property, randomized: a P2P stream between two survivor
/// nodes shares no links with the crashed node, so its completion timers
/// (start, finish, and the full per-channel roll-up) are bit-identical to
/// a crash-free run — for any seed and any mid-flight crash instant.
#[test]
fn noncrossing_p2p_timers_survive_remote_crash_randomized() {
    let mut pick = Rng::new(0xE1A57_1C);
    for case in 0..CASES {
        // 32MB drains in well under a millisecond of wire time; crash
        // somewhere inside the transfer.
        let crash_ns = 100_000 + pick.below(500_000);
        let sig = |crash: Option<u64>| {
            let mut cfg = Config::soak_defaults();
            cfg.topo.num_nodes = 3;
            cfg.seed = 0xBEEF + case;
            let mut s = ClusterSim::new(cfg);
            if let Some(at) = crash {
                s.inject_node_down(2, SimTime::ns(at));
                s.inject_node_up(2, SimTime::ms(200));
            }
            let id = s.submit_p2p(RankId(0), RankId(8), 32 << 20);
            assert!(s.run_until_op(id, 400_000_000), "stream must complete");
            let o = &s.ops[id.0];
            format!("{:?} {:?} {:?}", o.started_at, o.finished_at, o.chan_rollup)
        };
        assert_eq!(sig(Some(crash_ns)), sig(None), "case {case}: crash at {crash_ns}ns");
    }
}

/// Mid-shrink checkpoint: interrupt the simulation between the crash and
/// the requeued steps' re-issue (inside the elastic requeue delay, with
/// the aborted channel steps still pending in the event queue), restore,
/// and the resumed run finishes bit-identical to the uninterrupted one.
#[test]
fn mid_shrink_checkpoint_resume_is_bit_identical() {
    let run = |cut: bool| -> (u64, String) {
        let mut cfg = Config::soak_defaults();
        cfg.topo.num_nodes = 3;
        let mut s = ClusterSim::new(cfg.clone());
        s.inject_node_down(2, SimTime::ms(1));
        s.inject_node_up(2, SimTime::ms(300));
        let id = s.submit(CollKind::AllReduce, 64 << 20);
        // Stop inside the shrink's requeue delay (default 1 ms): the ring
        // is already rebuilt but the requeued OpSteps have not re-issued.
        s.run_until(SimTime::ms(1) + SimTime::us(200));
        assert_eq!(s.stats.elastic_shrinks, 1, "the crash must have shrunk the ring");
        assert!(!s.ops[id.0].is_done(), "the collective must still be mid-shrink");
        let mut s = if cut {
            let ckpt = s.checkpoint();
            ClusterSim::restore(cfg, &ckpt).expect("mid-shrink restore")
        } else {
            s
        };
        s.run_to_idle(400_000_000);
        assert!(s.ops[id.0].is_done(), "the collective must finish after the shrink");
        assert!(s.dead_nodes.iter().all(|d| !d), "the victim must rejoin");
        (s.now().as_ns(), s.checkpoint())
    };
    assert_eq!(run(true), run(false), "mid-shrink resume diverged");
}

/// Monitor memory stays O(window capacity) across a soak — the bounded
/// aggregates never grow with simulated time (satellite: bounded
/// WindowEstimator / Pinpointer regression at soak scale).
#[test]
fn monitor_memory_is_bounded_across_soak() {
    let measure = |bursts: u64| {
        let mut h = SoakHarness::with_params(Config::soak_defaults(), params(bursts, 0, 1));
        while !h.done() {
            h.run_burst();
        }
        let mon = h.sim.monitor.as_ref().unwrap();
        let samples: u64 = mon.active_ports().iter().map(|&p| mon.samples_total(p)).sum();
        (mon.memory_bytes(), samples)
    };
    // By 6 bursts every capped tail has saturated (≈15 samples per graded
    // port per burst vs a 64-entry cap) and the pinpointer trail is bounded
    // by its 2-period time horizon either way — so doubling the simulated
    // time from there may only add roll-up buckets (one per 2 bursts per
    // port), a sliver of the total.
    let (short_mem, short_samples) = measure(6);
    let (long_mem, long_samples) = measure(12);
    assert!(long_samples > short_samples * 3 / 2, "long soak must process more samples");
    assert!(
        long_mem <= short_mem + short_mem / 2,
        "monitor memory grew with soak length past the caps: {short_mem} -> {long_mem} bytes \
         ({short_samples} -> {long_samples} samples)"
    );
}

/// §Perf L6 satellite: the engine's cancellation tombstones must stay
/// memory-flat across soak-scale churn. A multi-day soak re-rates flows
/// millions of times, and the dominant pattern is cancel-after-fire — the
/// timer already popped by the time the re-rate invalidates it. Pre-L6
/// that leaked a tombstone per call forever (the seq matched nothing in
/// the heap, and nothing ever removed it); now the live-set accounting
/// refuses it outright, and genuine cancel-before-fire tombstones are
/// reaped as pops pass them. Ten times the churn must leave the same
/// (zero) backlog, not ten times the memory.
#[test]
fn engine_tombstones_stay_flat_across_soak_churn() {
    use vccl::sim::Engine;
    // Phase A: pure cancel-after-fire churn. Every round schedules a
    // burst, drains it, then cancels every already-fired id — twice, for
    // idempotence. The tombstone set must stay EMPTY throughout, at any
    // churn length.
    let after_fire_churn = |rounds: u64| {
        let mut e: Engine<u64> = Engine::new();
        let mut rng = Rng::new(0x7AB5);
        let mut peak = 0usize;
        for _ in 0..rounds {
            let ids: Vec<_> =
                (0..32).map(|i| e.schedule(SimTime::ns(1 + rng.below(10_000)), i)).collect();
            while e.pop().is_some() {}
            for id in ids {
                e.cancel(id);
                e.cancel(id);
            }
            peak = peak.max(e.cancelled_backlog());
        }
        peak
    };
    assert_eq!(after_fire_churn(300), 0, "cancel-after-fire must leave no tombstone");
    assert_eq!(after_fire_churn(3_000), 0, "10x the churn, same flat zero");

    // Phase B: genuine cancel-before-fire tombstones are bounded by the
    // queue and reaped by the pops that pass them — a drained engine holds
    // none, and the physical-queue invariant holds at every step.
    let mut e: Engine<u64> = Engine::new();
    let mut rng = Rng::new(0x7AB6);
    let mut cancelled = 0usize;
    let ids: Vec<_> =
        (0..2_000).map(|i| e.schedule(SimTime::ns(1 + rng.below(50_000)), i)).collect();
    for id in ids.iter().step_by(2) {
        e.cancel(*id);
        cancelled += 1;
    }
    assert_eq!(e.cancelled_backlog(), cancelled);
    assert_eq!(e.queued(), e.pending() + e.cancelled_backlog());
    let mut drained = 0usize;
    while e.pop().is_some() {
        drained += 1;
        assert_eq!(e.queued(), e.pending() + e.cancelled_backlog());
    }
    assert_eq!(drained, ids.len() - cancelled, "cancelled events must not fire");
    assert_eq!(e.cancelled_backlog(), 0, "a drained engine must hold zero tombstones");
    assert_eq!(e.pending(), 0);
}
