//! Fig 10 bench: P2P sweep per transport + simulator wall-time per op.

mod bench_util;
use vccl::ccl::ClusterSim;
use vccl::config::Config;
use vccl::topology::RankId;
use vccl::util::ByteSize;

fn main() {
    println!("== p2p_perf (Fig 10) ==");
    for (name, cfg) in [("vccl", Config::paper_defaults()), ("nccl", Config::nccl_baseline())] {
        for &mb in &[1u64, 64] {
            let label = format!("{name} inter-node sendrecv {mb}MB (sim)");
            bench_util::bench(&label, 10, || {
                let mut c = cfg.clone();
                c.vccl.channels = 2;
                let mut s = ClusterSim::new(c);
                let (_, op) = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(mb).0);
                assert!(op.is_done());
            });
        }
    }
    println!("\nfull table: `vccl exp fig10`");
}
