//! §Perf L4 acceptance gate: the RDMA layer's O(1) hot-path accounting —
//! the per-port running backlog counter read on every successful WC and the
//! port→QP reverse index walked on every flap — must do **≥10× fewer QP
//! visits** than the scan-based reference paths on a 64-node flap-churn
//! workload, and sustain a high event rate in wall-clock.
//!
//! Two measurement modes (mirroring `benches/flownet.rs`):
//! - default build: the reference cost is the conservative *analytic floor*
//!   (live QPs summed over backlog reads and flaps — exactly what the
//!   pre-L4 scans examined);
//! - `--features ref-alloc`: a second net is driven through the identical
//!   workload in `RdmaNet::set_reference_mode`, so the comparison (work
//!   counters *and* wall-clock) uses the real scans. Outputs are identical
//!   by contract — the run asserts the success counts match.
//!
//! The deterministic counters behind the gate are also emitted into
//! `BENCH_simcore.json` by `coordinator::bench::bench_simcore` (the
//! `simcore.rdma.*` suite), which CI uploads as the perf-trajectory
//! artifact.

mod bench_util;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use vccl::config::{NetConfig, TopologyConfig};
use vccl::net::{CompletionStatus, NetOutput, QpId, RdmaNet};
use vccl::sim::SimTime;
use vccl::topology::{Fabric, NicId, NodeId, PortId};
use vccl::util::Rng;

const NODES: usize = 64;
const RAILS: usize = 8;
const OPS: usize = 8_000;

fn port(node: usize, nic: usize) -> PortId {
    PortId { nic: NicId { node: NodeId(node), local: nic }, port: 0 }
}

/// Heap entry: (time, kind, a, b) with kind 0 = flow timer (flow, gen),
/// 1 = retry deadline (qp, epoch), 2 = warm-up release (qp, 0).
type Ev = Reverse<(SimTime, u8, u64, u32)>;

/// Seeded churn on a 64-node fabric: rail-aligned ring QPs (the collective
/// traffic shape), a steady stream of posts, port flaps whose heal times
/// straddle the hardware retry window (so some recover silently and some
/// drive QPs to error + proactive reset), and — like the monitor — one
/// `port_backlog_bytes` read per successful WC. Deterministic, so the
/// incremental and reference nets walk the exact same trajectory.
/// Returns (successful WCs, retry-exceeded WCs, summed backlog reads).
fn run_workload(net: &mut RdmaNet, fabric: &Fabric) -> (u64, u64, u64) {
    let mut rng = Rng::new(0x9DAA64);
    let qps: Vec<QpId> = (0..NODES)
        .flat_map(|n| (0..RAILS).map(move |r| (n, r)))
        .map(|(n, r)| net.create_qp(fabric, port(n, r), port((n + 1) % NODES, r)))
        .collect();
    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut now = SimTime::ZERO;
    let mut down: Vec<(PortId, SimTime)> = Vec::new(); // (port, heals at)
    let (mut successes, mut errors, mut backlog_sum) = (0u64, 0u64, 0u64);

    // Route one NetOutput (and whatever the WC handling spawns) fully.
    fn absorb(
        net: &mut RdmaNet,
        heap: &mut BinaryHeap<Ev>,
        now: SimTime,
        first: NetOutput,
        successes: &mut u64,
        errors: &mut u64,
        backlog_sum: &mut u64,
    ) {
        let mut pending = vec![first];
        while let Some(out) = pending.pop() {
            for t in out.timers {
                heap.push(Reverse((t.at, 0, t.flow.0, t.gen)));
            }
            for (qp, epoch, at) in out.retry_deadlines {
                heap.push(Reverse((at, 1, qp.0, epoch)));
            }
            for (qp, at) in out.warmups {
                heap.push(Reverse((at, 2, qp.0, 0)));
            }
            for wc in out.wcs {
                match wc.status {
                    CompletionStatus::Success => {
                        *successes += 1;
                        // The monitor's per-WC remaining-to-send read.
                        let src = net.qp_src(wc.qp);
                        *backlog_sum += net.port_backlog_bytes(src);
                    }
                    CompletionStatus::RetryExceeded => {
                        *errors += 1;
                        // VCCL's proactive reset keeps the QP in play.
                        pending.push(net.reset_to_rts(wc.qp, now));
                    }
                    CompletionStatus::WrFlushed => {}
                }
            }
        }
    }

    for _ in 0..OPS {
        now = now + SimTime::ns(rng.range(500, 40_000));
        // Heal every port whose flap expired.
        while let Some(pos) = down.iter().position(|&(_, at)| at <= now) {
            let (p, at) = down.swap_remove(pos);
            let out = net.set_port_up(fabric, p, true, at.max(now));
            absorb(net, &mut heap, now, out, &mut successes, &mut errors, &mut backlog_sum);
        }
        let roll = rng.below(100);
        if roll < 4 {
            // Port flap; heal times straddle the ≈8.4ms retry window, so
            // some flaps recover silently and some exhaust the window.
            let p = port(rng.below(NODES as u64) as usize, rng.below(RAILS as u64) as usize);
            if !down.iter().any(|&(d, _)| d == p) {
                let heal = now + SimTime::ns(rng.range(2_000_000, 30_000_000));
                down.push((p, heal));
                let out = net.set_port_up(fabric, p, false, now);
                absorb(net, &mut heap, now, out, &mut successes, &mut errors, &mut backlog_sum);
            }
        } else if roll < 55 || heap.is_empty() {
            let qp = qps[rng.below(qps.len() as u64) as usize];
            let (_, out) = net.post_send(qp, rng.range(128 << 10, 2 << 20), now, 0);
            absorb(net, &mut heap, now, out, &mut successes, &mut errors, &mut backlog_sum);
        } else if let Some(Reverse((at, kind, a, b))) = heap.pop() {
            now = now.max(at);
            let out = match kind {
                0 => net.on_flow_timer(vccl::net::FlowId(a), b, now),
                1 => net.on_retry_deadline(QpId(a), b, now),
                _ => net.on_warm(QpId(a), now),
            };
            absorb(net, &mut heap, now, out, &mut successes, &mut errors, &mut backlog_sum);
        }
    }
    // Drain the tail: no new posts, so the heap converges — in-flight flows
    // finish, stranded-on-dead-port QPs exhaust their windows and flush.
    // (Bounded as a runaway backstop; the workload converges far earlier.)
    let mut drain_budget = 200_000u32;
    while let Some(Reverse((at, kind, a, b))) = heap.pop() {
        now = now.max(at);
        let out = match kind {
            0 => net.on_flow_timer(vccl::net::FlowId(a), b, now),
            1 => net.on_retry_deadline(QpId(a), b, now),
            _ => net.on_warm(QpId(a), now),
        };
        absorb(net, &mut heap, now, out, &mut successes, &mut errors, &mut backlog_sum);
        drain_budget -= 1;
        if drain_budget == 0 {
            break;
        }
    }
    (successes, errors, backlog_sum)
}

fn fresh(fabric: &Fabric) -> RdmaNet {
    // Shrink the retry window (4.096us × 2^10 × 2 ≈ 8.4ms) and warm-up so
    // errors and resets actually cycle inside the sweep.
    let cfg = NetConfig {
        ib_timeout_exp: 10,
        ib_retry_cnt: 2,
        qp_warmup_ns: 5_000_000,
        ..Default::default()
    };
    RdmaNet::new(fabric, cfg)
}

fn main() {
    println!("== rdma: O(1) hot-path accounting (§Perf L4) ==");
    let fabric = Fabric::build(&TopologyConfig { num_nodes: NODES, ..Default::default() });

    // Wall-clock: churn throughput with the counter + index.
    bench_util::bench("rdma: 64-node flap churn, incremental", 5, || {
        let mut net = fresh(&fabric);
        let _ = run_workload(&mut net, &fabric);
    });

    // Work counters from one deterministic run.
    let mut net = fresh(&fabric);
    let (successes, errors, _) = run_workload(&mut net, &fabric);
    let w = net.rdma_stats();
    assert!(successes > 1_000, "workload too idle: {successes} successful WCs");
    assert!(errors > 20, "flaps must drive some QPs to error: {errors}");
    assert!(w.flap_events > 200, "flap churn too light: {}", w.flap_events);
    println!(
        "   qps {}  backlog reads {} (visits {})  flaps {} (visits {})  successes {}  errors {}",
        net.num_qps(),
        w.backlog_reads,
        w.backlog_qp_visits,
        w.flap_events,
        w.flap_qp_visits,
        successes,
        errors
    );

    // The reference run is timed once, not bench-looped: being painfully
    // slow at 512 QPs is precisely the point of this PR.
    #[cfg(feature = "ref-alloc")]
    let (ref_visits, ref_mode) = {
        let t0 = std::time::Instant::now();
        let mut refnet = fresh(&fabric);
        refnet.set_reference_mode(true);
        let (ref_successes, ref_errors, _) = run_workload(&mut refnet, &fabric);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("rdma: 64-node flap churn, reference scans          single run {ms:>9.3} ms");
        assert_eq!(
            (ref_successes, ref_errors),
            (successes, errors),
            "reference and incremental trajectories must be identical"
        );
        let rw = refnet.rdma_stats();
        (rw.backlog_qp_visits + rw.flap_qp_visits, "measured")
    };
    #[cfg(not(feature = "ref-alloc"))]
    let (ref_visits, ref_mode) = (w.backlog_scan_floor + w.flap_scan_floor, "analytic-floor");

    let visits = w.backlog_qp_visits + w.flap_qp_visits;
    let reduction = ref_visits as f64 / visits.max(1) as f64;
    println!(
        "=> reference QP visits ({ref_mode}): {ref_visits}  reduction: {reduction:.1}x (target ≥ 10x)"
    );
    assert!(
        reduction >= 10.0,
        "§Perf L4 target missed: {reduction:.1}x < 10x fewer QP visits per WC/flap"
    );
}
