//! §Perf L3 target: the DES core must sustain ≥1M events/s so that
//! cluster-scale experiments run in seconds.

mod bench_util;
use vccl::sim::{Engine, SimTime};

fn main() {
    println!("== simcore: event engine throughput ==");
    const N: u64 = 1_000_000;
    let med_ms = bench_util::bench("engine: schedule+pop 1M events", 10, || {
        let mut e: Engine<u64> = Engine::new();
        for i in 0..N {
            e.schedule(SimTime::ns(i % 1000), i);
        }
        while e.pop().is_some() {}
    });
    let evps = N as f64 / (med_ms / 1e3);
    println!("=> {evps:.2e} events/s (target ≥ 1e6)");
    assert!(evps > 1e6, "below §Perf target");

    bench_util::bench("engine: interleaved schedule/pop/cancel", 10, || {
        let mut e: Engine<u64> = Engine::new();
        let mut last = None;
        for i in 0..200_000u64 {
            let id = e.schedule(SimTime::ns(i % 64), i);
            if i % 3 == 0 {
                if let Some(prev) = last.take() {
                    e.cancel(prev);
                }
            }
            last = Some(id);
            if i % 2 == 0 {
                let _ = e.pop();
            }
        }
        while e.pop().is_some() {}
    });
}
