//! §Perf L3/L6 target: the DES core must sustain ≥1M events/s so that
//! cluster-scale experiments run in seconds. Since §Perf L6 the default
//! backend is a calendar queue; the workloads below cover its regimes —
//! hot-bucket FIFO traffic, mixed near/far scheduling that exercises the
//! overflow heap and idle-day jumps, and cancellation churn. With
//! `--features ref-alloc` the same mixed workload is also driven through
//! the reference binary heap for a side-by-side wall-clock comparison
//! (bit-identity between the two is pinned by the
//! `randomized_equivalence_*` tests in `src/sim/engine.rs`).

mod bench_util;
use vccl::sim::{Engine, SimTime};

const N: u64 = 1_000_000;

/// Mixed near/far pattern: dense same-bucket traffic, same-time bursts,
/// a slice of far-future events that ride the overflow heap, and enough
/// spread to roll the calendar window forward continuously.
fn mixed_workload(e: &mut Engine<u64>) {
    for i in 0..N {
        let far = match i % 97 {
            0 => 4_000_000,     // beyond the calendar day: overflow heap
            1..=4 => 200_000,   // a few buckets out
            _ => (i % 7) * 777, // hot-bucket steady state
        };
        e.schedule_at(e.now() + SimTime::ns(1 + far), i);
        if i % 2 == 0 {
            let _ = e.pop();
        }
    }
    while e.pop().is_some() {}
}

fn main() {
    println!("== simcore: event engine throughput ==");
    let med_ms = bench_util::bench("engine: schedule+pop 1M events (hot bucket)", 10, || {
        let mut e: Engine<u64> = Engine::new();
        for i in 0..N {
            e.schedule(SimTime::ns(i % 1000), i);
        }
        while e.pop().is_some() {}
    });
    let evps = N as f64 / (med_ms / 1e3);
    println!("=> {evps:.2e} events/s (target ≥ 1e6)");
    assert!(evps > 1e6, "below §Perf target");

    let cal_ms = bench_util::bench("engine: mixed near/far (calendar regimes)", 10, || {
        let mut e: Engine<u64> = Engine::new();
        mixed_workload(&mut e);
    });
    let evps = N as f64 / (cal_ms / 1e3);
    println!("=> {evps:.2e} events/s (target ≥ 1e6)");
    assert!(evps > 1e6, "mixed workload below §Perf target");

    #[cfg(feature = "ref-alloc")]
    {
        let ref_ms = bench_util::bench("engine: mixed near/far (reference heap)", 10, || {
            let mut e: Engine<u64> = Engine::new();
            e.set_reference_mode(true);
            mixed_workload(&mut e);
        });
        let ref_evps = N as f64 / (ref_ms / 1e3);
        println!(
            "=> reference heap {ref_evps:.2e} events/s (heap/calendar wall-clock = {:.2}x)",
            ref_ms / cal_ms.max(1e-9)
        );
        assert!(ref_evps > 1e6, "reference heap below §Perf target");
    }

    bench_util::bench("engine: interleaved schedule/pop/cancel", 10, || {
        let mut e: Engine<u64> = Engine::new();
        let mut last = None;
        for i in 0..200_000u64 {
            let id = e.schedule(SimTime::ns(i % 64), i);
            if i % 3 == 0 {
                if let Some(prev) = last.take() {
                    e.cancel(prev);
                }
            }
            last = Some(id);
            if i % 2 == 0 {
                let _ = e.pop();
            }
        }
        while e.pop().is_some() {}
    });
}
