//! Fig 13/14/18 bench: failover machinery cost + report regeneration.

mod bench_util;
use vccl::ccl::ClusterSim;
use vccl::config::Config;
use vccl::coordinator::reliability;
use vccl::sim::SimTime;
use vccl::topology::RankId;
use vccl::util::ByteSize;

fn main() {
    println!("== failover (Fig 13a/b, 14, 18) ==");
    bench_util::bench("port-down -> failover -> completion (sim)", 5, || {
        let mut cfg = Config::paper_defaults();
        cfg.net.ib_timeout_exp = 10;
        cfg.net.ib_retry_cnt = 2;
        cfg.net.qp_warmup_ns = 100_000_000;
        cfg.vccl.channels = 1;
        let mut s = ClusterSim::new(cfg);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(100_000_000);
        assert!(s.ops[id.0].is_done());
        assert_eq!(s.stats.failovers, 1);
    });
    let cfg = Config::paper_defaults();
    println!("\n{}", reliability::fig13b_training_under_failure(&cfg));
    println!("{}", reliability::fig18_multiport_stress(&cfg));
}
