//! Table 1 bench: regenerates the SM-utilization table and times it.

mod bench_util;
use vccl::config::Config;
use vccl::coordinator::experiments;

fn main() {
    println!("== sm_utilization (Table 1 / Table 4) ==");
    let cfg = Config::paper_defaults();
    bench_util::bench("table1 regeneration", 3, || {
        let r = experiments::table1_sm_utilization(&cfg);
        assert!(r.contains("alltoall"));
    });
    println!("\n{}", experiments::table1_sm_utilization(&cfg));
    println!("{}", experiments::table4_resource_consumption(&cfg));
}
