//! Fig 11 bench: 1F1B iteration under the three transports.

mod bench_util;
use vccl::ccl::ClusterSim;
use vccl::config::Config;
use vccl::coordinator::experiments;
use vccl::pipeline::{PipelineCfg, PipelineSim};

fn main() {
    println!("== training_throughput (Fig 11) ==");
    for (name, mk) in [
        ("vccl", Config::paper_defaults as fn() -> Config),
        ("ncclx", Config::ncclx_like),
        ("nccl", Config::nccl_baseline),
    ] {
        let label = format!("{name}: 1F1B iteration (PP=4, m=8, sim)");
        bench_util::bench(&label, 5, || {
            let cfg = mk();
            let pcfg = PipelineCfg::spread(&cfg, 4, 8);
            let mut p = PipelineSim::new(ClusterSim::new(cfg), pcfg);
            let r = p.run_iteration();
            assert!(!r.hung && !r.deadlocked);
        });
    }
    println!("\n{}", experiments::fig11_training_throughput(&Config::paper_defaults()));
}
