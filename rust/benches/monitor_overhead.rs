//! Table 5 bench: end-to-end cost of running with the monitor enabled.

mod bench_util;
use vccl::ccl::ClusterSim;
use vccl::config::Config;
use vccl::coordinator::observability;
use vccl::topology::RankId;
use vccl::util::ByteSize;

fn main() {
    println!("== monitor_overhead (Table 5) ==");
    for on in [false, true] {
        let label = format!("256MB p2p with monitor={on} (sim wall time)");
        bench_util::bench(&label, 5, || {
            let mut cfg = Config::paper_defaults();
            cfg.vccl.monitor = on;
            cfg.vccl.channels = 2;
            let mut s = ClusterSim::new(cfg);
            let (_, op) = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
            assert!(op.is_done());
        });
    }
    println!("\n{}", observability::table5_monitor_overhead(&Config::paper_defaults()));
}
