//! §Perf L3 acceptance gate: the incremental, component-scoped max-min
//! allocator must do **≥10× fewer flow-visits per network change** than the
//! global reference allocator on a 64-node workload, and sustain a high
//! reallocation rate in wall-clock.
//!
//! Two measurement modes:
//! - default build: the reference cost is the conservative *analytic floor*
//!   (live flows summed over changes — what a global pass settles/applies at
//!   minimum; its water-fill rounds rescan every flow and visit more);
//! - `--features ref-alloc`: a second net is driven through the identical
//!   workload in `FlowNet::set_reference_mode`, so the comparison (work
//!   counters *and* wall-clock) uses the real pre-L3 algorithm.
//!
//! Also emits `BENCH_simcore.json` so the perf trajectory of the simulator
//! core is tracked as a CI artifact: deterministic counters, plus one
//! wall-clock metric (`simcore.engine.events_per_sec`, the §Perf L6
//! scheduler headline — CI gates it at a generous floor; the tight
//! per-workload gates stay in `benches/simcore.rs`).

mod bench_util;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use vccl::config::TopologyConfig;
use vccl::coordinator::bench::{bench_simcore, BenchOpts};
use vccl::net::{FlowId, FlowMeta, FlowNet, FlowTimer};
use vccl::sim::SimTime;
use vccl::topology::{Fabric, NicId, NodeId, PortId};
use vccl::util::Rng;

const NODES: usize = 64;
const RAILS: usize = 8;
const OPS: usize = 6_000;
const TARGET_LIVE: usize = 192;

fn port(node: usize, nic: usize) -> PortId {
    PortId { nic: NicId { node: NodeId(node), local: nic }, port: 0 }
}

/// Seeded churn on a 64-node fabric: mostly rail-aligned flows (the ring
/// traffic shape), a slice of cross-rail spine traffic, and occasional port
/// flaps. Deterministic, so the incremental and reference nets walk the
/// exact same trajectory (their outputs are bit-identical by contract).
/// Returns the number of completed flows.
fn run_workload(net: &mut FlowNet, fabric: &Fabric) -> u64 {
    let mut rng = Rng::new(0xF10A11);
    let mut now = SimTime::ZERO;
    let mut heap: BinaryHeap<Reverse<(SimTime, u64, u32)>> = BinaryHeap::new();
    let mut live: Vec<FlowId> = Vec::new();
    let mut down: Vec<PortId> = Vec::new();
    let mut completed = 0u64;
    let mut meta = 0u64;
    fn push(heap: &mut BinaryHeap<Reverse<(SimTime, u64, u32)>>, ts: &[FlowTimer]) {
        heap.extend(ts.iter().map(|t| Reverse((t.at, t.flow.0, t.gen))));
    }
    for _ in 0..OPS {
        now = now + SimTime::ns(rng.range(50, 5_000));
        if rng.below(100) < 4 {
            // Port flap (batched tx+rx, like the RDMA layer does).
            if !down.is_empty() && rng.chance(0.7) {
                let p = down.swap_remove(rng.below(down.len() as u64) as usize);
                let ts = net.set_links_up(&fabric.port_links(p), true, now);
                push(&mut heap, &ts);
            } else {
                let p = port(rng.below(NODES as u64) as usize, rng.below(RAILS as u64) as usize);
                if !down.contains(&p) {
                    down.push(p);
                    let ts = net.set_links_up(&fabric.port_links(p), false, now);
                    push(&mut heap, &ts);
                }
            }
        } else if live.len() < TARGET_LIVE || heap.is_empty() {
            let node = rng.below(NODES as u64) as usize;
            let rail = rng.below(RAILS as u64) as usize;
            // 1 in 8 cross-rail: transits the spine trunks and merges
            // components, so the walk is exercised beyond singletons.
            let dst_rail = if rng.below(8) == 0 { (rail + 1) % RAILS } else { rail };
            let dst = (node + 1 + rng.below(4) as usize) % NODES;
            let path = fabric.path_inter(port(node, rail), port(dst, dst_rail));
            meta += 1;
            let (id, ts) =
                net.start(now, path, rng.range(256 << 10, 4 << 20), rng.range(0, 5_000), FlowMeta(meta));
            live.push(id);
            push(&mut heap, &ts);
        } else if let Some(Reverse((at, flow, gen))) = heap.pop() {
            let fire = at.max(now);
            now = fire;
            let (m, ts) = net.try_finish(FlowId(flow), gen, fire);
            if m.is_some() {
                completed += 1;
                live.retain(|&i| i != FlowId(flow));
            }
            push(&mut heap, &ts);
        }
    }
    completed
}

fn fresh(fabric: &Fabric) -> FlowNet {
    FlowNet::from_fabric(fabric, 0.97, 0.35)
}

fn main() {
    println!("== flownet: incremental max-min allocator (§Perf L3) ==");
    let fabric = Fabric::build(&TopologyConfig { num_nodes: NODES, ..Default::default() });

    // Wall-clock: reallocation throughput of the incremental allocator.
    bench_util::bench("flownet: 64-node churn, incremental", 5, || {
        let mut net = fresh(&fabric);
        let _ = run_workload(&mut net, &fabric);
    });

    // Work counters from one deterministic run.
    let mut net = fresh(&fabric);
    let completed = run_workload(&mut net, &fabric);
    let a = net.alloc_stats();
    assert!(completed > 500, "workload too idle: {completed} completions");
    println!(
        "   changes {}  incremental visits {}  (max component {} flows, {} completions)",
        a.changes, a.flow_visits, a.max_component, completed
    );

    // The reference run is timed once, not bench-looped: being painfully
    // slow at 64 nodes is precisely the point of this PR.
    #[cfg(feature = "ref-alloc")]
    let (ref_visits, ref_mode) = {
        let t0 = std::time::Instant::now();
        let mut refnet = fresh(&fabric);
        refnet.set_reference_mode(true);
        let ref_completed = run_workload(&mut refnet, &fabric);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("flownet: 64-node churn, global reference          single run {ms:>9.3} ms");
        assert_eq!(
            ref_completed, completed,
            "reference and incremental trajectories must be identical"
        );
        (refnet.alloc_stats().flow_visits, "measured")
    };
    #[cfg(not(feature = "ref-alloc"))]
    let (ref_visits, ref_mode) = (a.global_floor, "analytic-floor");

    let reduction = ref_visits as f64 / a.flow_visits.max(1) as f64;
    println!("=> reference visits ({ref_mode}): {ref_visits}  reduction: {reduction:.1}x (target ≥ 10x)");
    assert!(
        reduction >= 10.0,
        "§Perf L3 target missed: {reduction:.1}x < 10x fewer flow-visits per change"
    );

    // BENCH_simcore.json: the library's deterministic allocator counters
    // (16-node AllReduce) plus this bench's 64-node churn counters.
    let mut report = bench_simcore(&vccl::config::Config::paper_defaults(), &BenchOpts::default());
    report.push("simcore.flownet.changes", a.changes as f64, "count");
    report.push("simcore.flownet.flow_visits_incremental", a.flow_visits as f64, "count");
    report.push("simcore.flownet.flow_visits_reference", ref_visits as f64, "count");
    report.push("simcore.flownet.visit_reduction_x", reduction, "ratio");
    report.push("simcore.flownet.max_component_flows", a.max_component as f64, "count");
    report.push("simcore.flownet.completed_flows", completed as f64, "count");
    // NOTE: cargo runs bench binaries with cwd = the package root (rust/),
    // so callers wanting a specific location should pass an absolute --out.
    let out = std::env::args()
        .skip_while(|arg| arg != "--out")
        .nth(1)
        .unwrap_or_else(|| "BENCH_simcore.json".to_string());
    if let Some(dir) = std::path::Path::new(&out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).expect("creating BENCH output dir");
        }
    }
    std::fs::write(&out, report.to_json()).expect("writing BENCH_simcore.json");
    println!("wrote {out}");
}
