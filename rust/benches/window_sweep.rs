//! Fig 19 bench: estimator throughput + window-size fidelity sweep.

mod bench_util;
use vccl::config::Config;
use vccl::coordinator::observability;
use vccl::monitor::{MsgRecord, WindowEstimator};
use vccl::sim::SimTime;

fn main() {
    println!("== window_sweep (Fig 19 / Appendix H) ==");
    const N: usize = 1_000_000;
    for w in [1usize, 8, 32] {
        let label = format!("estimator push x1M (W={w})");
        let med = bench_util::bench(&label, 5, || {
            let mut e = WindowEstimator::new(w);
            for i in 0..N as u64 {
                e.push(MsgRecord {
                    posted_at: SimTime::ns(i * 20),
                    completed_at: SimTime::ns(i * 20 + 21),
                    bytes: 1 << 20,
                });
            }
        });
        println!("   -> {:.0} ns/WC (the Table 5 'CPU overhead' unit cost)", med * 1e6 / N as f64);
    }
    println!("\n{}", observability::fig19_window_sweep(&Config::paper_defaults()));
}
