//! Minimal bench harness shared by all benches (criterion is unavailable
//! in the offline vendored build): N timed iterations, median + MAD report.
#![allow(dead_code)]

use std::time::Instant;

pub fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> f64 {
    // Warmup.
    f();
    let mut samples: Vec<f64> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    let min = samples[0];
    let max = samples[samples.len() - 1];
    println!("{name:50} median {med:>9.3} ms   (min {min:.3} / max {max:.3}, n={iters})");
    med
}
