//! §Perf L5 acceptance gate: transfer bookkeeping must be **O(active)**,
//! not O(history) — on a scale64 (64-node / 512-rank) ring AllReduce the
//! peak number of live `Xfer` slots must be **≥100× below** the total
//! transfers created. Before the recycling slab, every chunked transfer
//! stayed resident in `ClusterSim::xfers` forever (~8.4M records per
//! scale256 AllReduce), which made memory the 256-node ceiling; the
//! `scale512` experiment (~33.5M transfers) is what this gate unlocks.
//!
//! Two measurement modes (mirroring `benches/flownet.rs` / `benches/rdma.rs`):
//! - default build: the recycling slab runs and the gate compares its peak
//!   live count against the created count (both deterministic);
//! - `--features ref-alloc`: a second simulation is driven through the
//!   identical workload in retain-everything reference mode
//!   (`ClusterSim::set_xfer_retain_all`). Outputs are identical by
//!   contract — the run asserts completion time and event counts match —
//!   and the reference's resident slot count equals the created count,
//!   which is exactly the memory the recycling build does NOT pay.
//!
//! The deterministic counters behind the gate also ship in
//! `BENCH_simcore.json` (the `simcore.mem.*` / `simcore.mem64.*` suites
//! emitted by `coordinator::bench::bench_simcore`), which CI uploads as
//! the perf-trajectory artifact.

mod bench_util;

use vccl::ccl::{ClusterSim, CollKind, XferMemStats};
use vccl::config::Config;
use vccl::util::ByteSize;

/// One scale64 ring AllReduce. Returns the slab counters plus the outputs
/// the reference-mode comparison pins (finish time, dispatched events).
fn run_scale64_allreduce(retain: bool) -> (XferMemStats, u64, u64) {
    let mut s = ClusterSim::new(Config::scale64());
    if retain {
        #[cfg(feature = "ref-alloc")]
        s.set_xfer_retain_all(true);
        #[cfg(not(feature = "ref-alloc"))]
        unreachable!("retain-everything mode needs --features ref-alloc");
    }
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(32).0);
    s.run_to_idle(400_000_000);
    assert!(s.ops[id.0].is_done(), "scale64 allreduce must complete");
    // The per-op roll-up carries the figures the retired records used to:
    // with no failure injected, wire chunks == delivered chunks exactly
    // (a phantom transmission into a recycled slot would break this).
    let o = &s.ops[id.0];
    let wire: u64 = o.chan_rollup.iter().map(|c| c.chunks_wire).sum();
    let delivered: u64 = o.chan_rollup.iter().map(|c| c.chunks).sum();
    assert_eq!(wire, delivered, "roll-up chunk conservation must balance");
    (
        s.xfers.mem_stats(),
        o.finished_at.expect("finished").as_ns(),
        s.engine.dispatched(),
    )
}

fn main() {
    println!("== xfer_slab: O(active) transfer bookkeeping (§Perf L5) ==");

    // Wall-clock: the recycling slab on the gate workload.
    bench_util::bench("xfer_slab: scale64 allreduce, recycling", 3, || {
        let _ = run_scale64_allreduce(false);
    });

    // Deterministic counters from one run.
    let (m, finish_ns, dispatched) = run_scale64_allreduce(false);
    println!(
        "   created {}  retired {}  peak live {}  resident slots {}",
        m.created, m.retired, m.high_water, m.slots_resident
    );
    assert!(m.created > 100_000, "workload too small: {} transfers", m.created);
    assert_eq!(m.live, 0, "every transfer must retire at quiescence");
    assert!(
        m.slots_resident <= m.high_water,
        "recycling must cap resident slots at the live peak"
    );

    // The reference run is timed once, not bench-looped: retaining ~0.5M
    // records is precisely the cost this PR removes.
    #[cfg(feature = "ref-alloc")]
    {
        let t0 = std::time::Instant::now();
        let (rm, rfinish, rdispatched) = run_scale64_allreduce(true);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("xfer_slab: scale64 allreduce, retain-everything    single run {ms:>9.3} ms");
        assert_eq!(
            (rfinish, rdispatched),
            (finish_ns, dispatched),
            "retained and recycling trajectories must be identical"
        );
        assert_eq!(
            (rm.created, rm.retired, rm.live, rm.high_water),
            (m.created, m.retired, m.live, m.high_water),
            "live accounting is mode-independent"
        );
        assert_eq!(
            rm.slots_resident, rm.created,
            "the reference retains every record"
        );
        println!(
            "   reference resident slots: {} ({}x the recycling build's {})",
            rm.slots_resident,
            rm.slots_resident / m.slots_resident.max(1),
            m.slots_resident
        );
    }

    let ratio = m.created as f64 / m.high_water.max(1) as f64;
    println!(
        "=> transfers created: {}  peak live slots: {}  ratio: {ratio:.1}x (target ≥ 100x)",
        m.created, m.high_water
    );
    assert!(
        ratio >= 100.0,
        "§Perf L5 target missed: {ratio:.1}x < 100x fewer live slots than transfers created"
    );
    let _ = finish_ns;
    let _ = dispatched;
}
